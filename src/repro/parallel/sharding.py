"""Sharding rules: param-path patterns → PartitionSpec (DP/FSDP/TP/EP/SP).

Axes: ``pod`` (inter-pod DP), ``data`` (DP / FSDP / SP), ``model`` (TP / EP).
GSPMD handles non-divisible dims by implicit padding (qwen's 40 heads,
llama3.2-3b's 24 heads, grok's 8 experts — documented per config).
"""

from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

BATCH_AXES = ("pod", "data")


def _rules(cfg: ModelConfig, fsdp: bool) -> list[tuple[str, P]]:
    """Ordered (regex, spec); first match wins.  Paths look like
    ``units/0/attn/wq`` or ``embed/tok``."""
    if cfg.shard_mode == "dp_sp":
        # replicated weights (sequence parallelism carries the model axis)
        d = "data" if fsdp else None
        return [(r".*", P(d))] if fsdp else [(r".*", P())]
    if cfg.shard_mode == "zero3":
        # pure data parallelism with fully-sharded params/grads/optimizer
        # (ZeRO-3): batch over (data, model); params sharded dim0 over both
        # axes; per-layer all-gather on use, reduce-scatter on grads — the
        # right scheme for ≤30B dense training (EXPERIMENTS.md §Perf cell A)
        return [
            (r"(norm|_norm|lam|A_log|/D$|dt_bias|conv_[wb]|b[qkv]$)", P()),
            (r".*", P(("data", "model"))),
        ]
    d = "data" if fsdp else None  # FSDP shards the non-TP dim over data
    expert_mode = cfg.moe_shard_mode == "expert"
    return [
        # embeddings
        (r"embed/tok$", P("model", d)),
        (r"embed/head$", P(d, "model")),
        # attention
        (r"attn/wq$", P(d, "model")),
        (r"attn/wk$", P(d, "model")),
        (r"attn/wv$", P(d, "model")),
        (r"attn/wo$", P("model", d)),
        (r"attn/b[qkv]$", P("model")),
        # dense mlp + shared experts
        (r"(mlp|shared)/w_(up|gate)$", P(d, "model")),
        (r"(mlp|shared)/w_down$", P("model", d)),
        # MoE experts: EP over model (deepseek) or per-expert TP (grok)
        (r"moe/router$", P()),
        (r"moe/w_(up|gate)$", P("model", d, None) if expert_mode else P(None, d, "model")),
        (r"moe/w_down$", P("model", d, None) if expert_mode else P(None, "model", d)),
        # mamba-2
        (r"ssm/w_in$", P(d, "model")),
        (r"ssm/w_out$", P("model", d)),
        (r"ssm/conv_[wb]$", P()),
        (r"ssm/(A_log|D|dt_bias|norm_w)$", P()),
        # RG-LRU
        (r"rec/w_[xy]$", P(d, "model")),
        (r"rec/w_[ri]$", P(None, "model")),
        (r"rec/w_out$", P("model", d)),
        (r"rec/(conv_[wb]|lam)$", P()),
        # norms and anything small
        (r"(norm|_norm)", P()),
        (r".*", P()),
    ]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def sanitize_spec(spec: P, shape, mesh: Mesh | None) -> P:
    """jit argument shardings must tile evenly: drop (replicate) any axis
    whose size doesn't divide the dim (e.g. mamba2's vocab 50280 on a 16-way
    model axis — noted as replication waste in the dry-run record)."""
    if mesh is None:
        return spec
    sizes = dict(mesh.shape)  # works for Mesh and AbstractMesh
    out = []
    for i, entry in enumerate(tuple(spec)):
        if entry is None or i >= len(shape):
            out.append(None if i >= len(shape) else entry)
            continue
        axes = entry if isinstance(entry, (tuple, list)) else (entry,)
        total = 1
        for a in axes:
            total *= sizes.get(a, 1)
        out.append(entry if total and shape[i] % total == 0 else None)
    return P(*out)


def param_specs(params_or_shapes, cfg: ModelConfig, fsdp: bool = False, mesh: Mesh | None = None):
    """PartitionSpec pytree matching the param tree (works on
    ShapeDtypeStructs for the dry-run)."""
    rules = _rules(cfg, fsdp)

    def spec_for(path, leaf):
        s = _path_str(path)
        # scan-stacked unit params carry a leading n_units dim; optimizer
        # state mirrors the tree under m/... v/... s/... prefixes
        stacked = "units/" in s
        for pat, spec in rules:
            if re.search(pat, s):
                spec = _trim_to_rank(spec, leaf, stacked)
                return sanitize_spec(spec, leaf.shape, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params_or_shapes)


def _trim_to_rank(spec: P, leaf, stacked: bool) -> P:
    ndim = len(leaf.shape)
    tup = tuple(spec)
    if stacked:
        tup = (None,) + tup  # leading n_units dim
    tup = tup[:ndim]
    tup = tup + (None,) * (ndim - len(tup))
    return P(*tup)


def batch_specs(batch, *, seq_parallel: bool = False, mesh: Mesh | None = None,
                axes: tuple = BATCH_AXES):
    """Input sharding: batch over ``axes``; optional sequence-parallel."""

    def spec_for(path, leaf):
        ndim = len(leaf.shape)
        name = _path_str(path)
        if ndim == 0:
            return P()
        if name.endswith("position"):
            return P()
        if seq_parallel and ndim >= 2:
            # batch on (pod, data); sequence on the model axis
            spec = P(axes, "model", *([None] * (ndim - 2)))
        else:
            spec = P(axes, *([None] * (ndim - 1)))
        return sanitize_spec(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, batch)


def cache_specs(caches, cfg: ModelConfig, mesh: Mesh | None = None):
    """KV/state cache sharding: batch over (pod, data); kv-heads/feature dims
    over model where they exist."""

    def spec_for(path, leaf):
        s = _path_str(path)
        ndim = len(leaf.shape)
        stacked = "units" in s
        off = 1 if stacked else 0
        if s.endswith("/pos"):
            return P(*([None] * ndim))
        if s.endswith(("/k", "/v", "/k_scale", "/v_scale")):
            # [*, B, S, K, hd] (scales: [*, B, S, K])
            tup = [None] * ndim
            tup[off] = BATCH_AXES
            if cfg.shard_mode == "dp_sp":
                tup[off + 1] = "model"  # cache sequence-sharded
            else:
                tup[off + 2] = "model"  # cache kv-head-sharded
            return P(*tup)
        if s.endswith("/conv"):
            # [*, B, W-1, C]: batch only (C is a z/B/C concat; keep replicated)
            tup = [None] * ndim
            tup[off] = BATCH_AXES
            return P(*tup)
        if s.endswith("/h"):
            # ssm [*, B, nh, hp, ds] / rec [*, B, w]: shard heads/width on model
            tup = [None] * ndim
            tup[off] = BATCH_AXES
            if ndim - off >= 2:
                tup[off + 1] = "model"
            return P(*tup)
        return P(*([None] * ndim))

    def spec_sanitized(path, leaf):
        return sanitize_spec(spec_for(path, leaf), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_sanitized, caches)


def filter_spec(spec: P, mesh: Mesh) -> P:
    """Drop axis names the mesh doesn't have (e.g. 'pod' on a single-pod
    mesh) so one rule set serves every mesh."""
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            if not kept:
                return None
            return kept[0] if len(kept) == 1 else kept
        return entry if entry in names else None

    return P(*(keep(e) for e in tuple(spec)))


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, filter_spec(s, mesh)), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
