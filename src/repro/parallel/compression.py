"""Gradient compression: int8 error-feedback all-reduce (distributed-opt trick).

Structure = quantized reduce-scatter (all_to_all of int8 segments) → local
dequant-sum → requantize → int8 all-gather.  Per-device wire bytes ≈ 2N·1B
versus a ring fp32 all-reduce's ≈ 8N·1B → ~4× ICI saving on the gradient
exchange.  int8 rounding of the *contribution* is absorbed by per-device
error feedback (the residual is carried to the next step, so the accumulated
update is unbiased); the post-reduction requantization error is shared and
bounded by 1/127 of the segment max.

Usage (inside shard_map with the data axis bound):
    g_sync, err = compressed_psum_mean(g_local, err, axis_name="data")
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

CHUNK = 256  # per-scale quantization group


def _quantize_rows(x: jnp.ndarray):
    """x: [R, M] fp32, M % CHUNK == 0 → (int8 [R, M], scales [R, M/CHUNK])."""
    R, M = x.shape
    xp = x.reshape(R, M // CHUNK, CHUNK)
    scale = jnp.maximum(jnp.max(jnp.abs(xp), axis=-1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xp / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(R, M), scale.astype(jnp.float32)


def _dequantize_rows(q: jnp.ndarray, scale: jnp.ndarray):
    R, M = q.shape
    x = q.astype(jnp.float32).reshape(R, M // CHUNK, CHUNK) * scale[..., None]
    return x.reshape(R, M)


def compressed_psum_mean(g: jnp.ndarray, err: jnp.ndarray, axis_name: str):
    """Error-feedback int8 mean-reduce over ``axis_name`` (shard_map only).

    Returns (mean gradient, new local error residual)."""
    # jax.lax.axis_size is missing on older jax; psum(1) is the portable size
    _axis_size = getattr(jax.lax, "axis_size", None)
    D = _axis_size(axis_name) if _axis_size else jax.lax.psum(1, axis_name)
    n = g.size
    flat = g.reshape(-1).astype(jnp.float32) + err.reshape(-1)
    seg = -(-n // (D * CHUNK)) * CHUNK  # segment length, CHUNK-aligned
    pad = D * seg - n
    flat_p = jnp.pad(flat, (0, pad)).reshape(D, seg)

    # quantize my contribution, remember what was actually sent (EF residual)
    q, s = _quantize_rows(flat_p)
    new_err = (flat_p - _dequantize_rows(q, s)).reshape(-1)[:n]

    # reduce-scatter: device i ends up owning segment i from every peer
    q_x = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0, tiled=True)
    s_x = jax.lax.all_to_all(s, axis_name, split_axis=0, concat_axis=0, tiled=True)
    own = _dequantize_rows(q_x.reshape(D, seg), s_x.reshape(D, -1)).sum(axis=0) / D  # [seg]

    # requantize the reduced segment and all-gather it back
    q2, s2 = _quantize_rows(own[None, :])
    q_all = jax.lax.all_gather(q2[0], axis_name)  # [D, seg] int8
    s_all = jax.lax.all_gather(s2[0], axis_name)  # [D, seg/CHUNK]
    mean = _dequantize_rows(q_all, s_all).reshape(-1)[:n]
    return mean.reshape(g.shape), new_err.reshape(g.shape)


def uncompressed_psum_mean(g: jnp.ndarray, axis_name: str):
    d = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return jax.lax.psum(g.astype(jnp.float32), axis_name) / d
