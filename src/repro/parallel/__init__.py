"""repro.parallel"""
