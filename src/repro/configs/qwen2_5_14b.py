"""qwen2.5-14b [dense]: 48L d=5120 40H (GQA kv=8) ff=13824 v=152064;
QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]
TP note: 40H % 16 != 0 → GSPMD pads heads to 48 under 16-way TP (20% pad,
attention only).  long_500k: SKIP — full attention."""

import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab=152064, qkv_bias=True, rope_theta=1000000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2.5-smoke", n_layers=2, d_model=80, n_heads=5,
    n_kv_heads=1, d_ff=160, vocab=256,
)
