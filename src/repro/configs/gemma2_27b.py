"""gemma2-27b [dense]: 46L d=4608 32H (GQA kv=16) ff=36864 v=256000;
local(4096)+global alternating, attn softcap 50, final softcap 30, tied
embeddings.  [arXiv:2408.00118; hf]
long_500k: SKIP — the global layers are full attention at 500k
(local-only layers would qualify, the arch as a whole does not)."""

import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
    d_ff=36864, vocab=256000, head_dim=128,
    unit=("local", "global"), window=4096,
    attn_softcap=50.0, final_softcap=30.0, tie_embeddings=True, act="gelu",
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma2-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, head_dim=16, window=8,
)
