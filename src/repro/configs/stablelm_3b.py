"""stablelm-3b [dense]: 32L d=2560 32H (kv=32, MHA) ff=6912 v=50304.
[hf:stabilityai/stablelm-2-1_6b; unverified]
TP note: 32H/16 = 2 heads/shard exact; vocab 50304 = 16·3144 exact.
long_500k: SKIP — full attention."""

import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab=50304,
)

SMOKE = dataclasses.replace(
    CONFIG, name="stablelm-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256,
)
