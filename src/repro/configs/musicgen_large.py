"""musicgen-large [audio]: 48L d=2048 32H (kv=32, MHA) ff=8192 v=2048;
decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]
The EnCodec frontend is a stub: the model consumes codec token ids directly
(vocab=2048); non-gated GELU FFN (standard transformer FFN, as in MusicGen).
long_500k: SKIP — full attention."""

import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, mlp_gated=False, act="gelu",
)

SMOKE = dataclasses.replace(
    CONFIG, name="musicgen-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=64,
)
