"""grok-1-314b [moe]: 64L d=6144 48H (GQA kv=8) ff=32768 v=131072;
8 experts top-2.  [hf:xai-org/grok-1; unverified]
EP note: 8 experts < 16-way model axis → expert weights shard d_ff
(moe_shard_mode="ffn"); memory plan requires FSDP (DESIGN.md §7).
long_500k: SKIP — full attention."""

import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072,
    unit=("moe",), n_experts=8, n_shared_experts=0, top_k=2,
    moe_shard_mode="ffn",
)

SMOKE = dataclasses.replace(
    CONFIG, name="grok-1-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, n_experts=4, top_k=2,
)
