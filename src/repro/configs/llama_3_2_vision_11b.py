"""llama-3.2-vision-11b [vlm]: 40L d=4096 32H (GQA kv=8) ff=14336 v=128256.

Cross-attention image layers every 5th layer (8 of 40); the vision frontend
is a stub — input_specs() supplies precomputed patch embeddings
[B, 1601, d_model] (1600 patches + CLS at 448px/14px patch).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
TP note: 32H/16-way model axis = 2 heads/shard (exact); kv=8 < 16 → GSPMD
replica-pads KV heads (documented waste, see EXPERIMENTS.md §Perf).
long_500k: SKIP — full attention (DESIGN.md §6)."""

import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256,
    unit=("global", "global", "global", "global", "cross"),
    rope_theta=500000.0, cross_kv_len=1601,
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama-3.2-vision-smoke", n_layers=5, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, cross_kv_len=16,
)
