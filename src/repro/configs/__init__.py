from .base import SHAPES, ModelConfig, ShapeCell, input_specs
from .registry import ARCHS, all_configs, get_config, get_smoke_config
