"""deepseek-moe-16b [moe]: 28L d=2048 16H (kv=16, MHA) ff=1408/expert
v=102400; 2 shared + 64 routed top-6 (fine-grained experts).
[arXiv:2401.06066; hf]
EP note: 64 experts / 16-way model axis = 4 experts/shard (exact).
long_500k: SKIP — full attention."""

import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    unit=("moe",), n_experts=64, n_shared_experts=2, top_k=6,
    moe_shard_mode="expert",
)

SMOKE = dataclasses.replace(
    CONFIG, name="deepseek-moe-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=32, vocab=256, n_experts=8, top_k=2, n_shared_experts=1,
)
