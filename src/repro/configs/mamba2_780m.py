"""mamba2-780m [ssm]: 48L d=1536, attention-free, v=50280, ssm_state=128;
SSD (state-space duality).  [arXiv:2405.21060; unverified]
d_inner=3072, headdim=64 → 48 SSD heads.  long_500k: RUNS — O(1) decode
state; this is the paper's best-case workload (matrix-vector, no reuse)."""

import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    unit=("ssm",), ssm_state=128, ssm_headdim=64, ssm_expand=2,
    ssm_chunk=128, supports_long_context=True, mlp_gated=False,
)

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-smoke", n_layers=2, d_model=64, vocab=256,
    ssm_state=16, ssm_headdim=16, ssm_chunk=8,
)
