"""recurrentgemma-9b [hybrid]: 38L d=4096 16H? (brief: GQA kv=1 → MQA)
ff=12288 v=256000; RG-LRU + local attn 1:2.  [arXiv:2402.19427; unverified]
Pattern (rec, rec, local)×12 + 2-layer tail (38 = 12·3 + 2).
long_500k: RUNS — bounded local window (2048) + O(1) RG-LRU state."""

import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    unit=("rec", "rec", "local"), window=2048, lru_width=4096,
    tie_embeddings=True, act="gelu", supports_long_context=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="recurrentgemma-smoke", n_layers=5, d_model=64, n_heads=4,
    n_kv_heads=1, d_ff=128, vocab=256, head_dim=16, window=8, lru_width=64,
)
