"""llama3.2-3b [dense]: 28L d=3072 24H (GQA kv=8) ff=8192 v=128256.
[hf:meta-llama/Llama-3.2-1B; unverified]
TP note: 24H % 16 != 0 → GSPMD pads heads to 32 under 16-way TP (25% pad on
attention only; hillclimb candidate: 8-way head × 2-way d_ff factoring).
long_500k: SKIP — full attention."""

import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=128256, rope_theta=500000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama3.2-smoke", n_layers=2, d_model=48, n_heads=6,
    n_kv_heads=2, d_ff=96, vocab=256,
)
