"""Architecture registry: the 10 assigned configs + reduced smoke variants.

Exact dims from the assignment brief; per-arch notes record TP divisibility
and long-context applicability (DESIGN.md §6)."""

from __future__ import annotations

import dataclasses
import importlib

ARCHS = [
    "llama_3_2_vision_11b",
    "deepseek_moe_16b",
    "grok_1_314b",
    "stablelm_3b",
    "llama3_2_3b",
    "gemma2_27b",
    "qwen2_5_14b",
    "mamba2_780m",
    "musicgen_large",
    "recurrentgemma_9b",
]

def _norm(name: str) -> str:
    """Map display names ('llama-3.2-vision-11b', 'qwen2.5-14b') to modules."""
    n = name.replace("-", "_").replace(".", "_")
    if n in ARCHS:
        return n
    for a in ARCHS:
        if n.replace("_", "") == a.replace("_", ""):
            return a
    raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")


def get_config(name: str):
    return importlib.import_module(f"repro.configs.{_norm(name)}").CONFIG


def get_smoke_config(name: str):
    """Reduced same-family config: small dims, few layers/experts — runs a
    forward/train step on CPU (the full config is dry-run-only)."""
    return importlib.import_module(f"repro.configs.{_norm(name)}").SMOKE


def all_configs():
    return {a: get_config(a) for a in ARCHS}
