"""Model/run configuration schema shared by all assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 → d_model // n_heads
    # layer-kind pattern, repeated n_layers // len(unit) times (+ tail)
    # kinds: global | local | cross | moe | ssm | rec
    unit: tuple[str, ...] = ("global",)
    window: int = 4096
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    attn_softcap: float = 0.0  # 0 → off (gemma2: 50.0)
    final_softcap: float = 0.0  # gemma2: 30.0
    tie_embeddings: bool = False
    mlp_gated: bool = True
    act: str = "silu"  # silu | gelu
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_shard_mode: str = "expert"  # expert-parallel vs ffn tensor-parallel
    capacity_factor: float = 1.25
    # SSM (mamba-2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_width: int = 4
    # RG-LRU (recurrentgemma)
    lru_width: int = 0  # 0 → d_model
    # vlm / audio stubs: cross-attention context length from the frontend
    cross_kv_len: int = 0
    # numerics / memory
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"  # none | full | dots
    norm_eps: float = 1e-6
    # unroll the layer scan (dry-run: exact cost_analysis — XLA counts scan
    # bodies once, so scanned models under-report FLOPs/collectives by ~depth)
    unroll_layers: bool = False
    # ---- §Perf hillclimb switches (EXPERIMENTS.md §Perf; default = paper-
    # faithful/naive baseline) ----
    # repeat KV heads to the query-head count before attention: keeps every
    # attention einsum head-aligned with the TP sharding, so GSPMD stops
    # inserting a reshard inside each flash block pair
    opt_attn_layout: bool = False
    # checkpoint the inner flash kv-step: backward recomputes the [bq,bk]
    # probability block instead of saving it per step (flash-style backward)
    opt_flash_remat: bool = False
    # int8 KV cache (serving): halves decode memory traffic vs bf16
    opt_kv_quant: bool = False
    # pad query heads to a TP-divisible count (e.g. 24→32, 40→48 on a 16-way
    # model axis) with zero wq rows / wo cols — numerics exact, stops GSPMD
    # from sharding head_dim (which puts an all-reduce inside every flash
    # block pair)
    pad_heads_to: int = 0
    # flash-attention block sizes: larger bq cuts KV re-streaming (HBM
    # traffic scales with nq = T/bq) at the cost of VMEM per block
    attn_bq: int = 512
    attn_bk: int = 512
    # sharding scheme: "tp" = Megatron-style tensor parallel on the model
    # axis (baseline); "dp_sp" = replicated weights + sequence parallelism
    # over the model axis (the right scheme for small models at prefill —
    # see EXPERIMENTS.md §Perf cell B)
    shard_mode: str = "tp"
    # which shape cells this arch supports (DESIGN.md §6)
    supports_long_context: bool = False

    # ------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.unit)

    @property
    def tail(self) -> tuple[str, ...]:
        return self.unit[: self.n_layers % len(self.unit)]

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def lru_dim(self) -> int:
        return self.lru_width or self.d_model

    def layer_kinds(self) -> list[str]:
        return list(self.unit) * self.n_units + list(self.tail)

    # ------------------------------------------------------ analytics
    def param_count(self) -> int:
        """Exact parameter count (embeddings included)."""
        d, hd = self.d_model, self.hd
        n = self.vocab * d  # embed
        if not self.tie_embeddings:
            n += self.vocab * d
        for kind in self.layer_kinds():
            n += self._layer_params(kind)
        n += d  # final norm
        return n

    def _layer_params(self, kind: str) -> int:
        d, hd = self.d_model, self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        mlp = d * self.d_ff * (3 if self.mlp_gated else 2)
        norms = 2 * d
        if kind in ("global", "local", "cross"):
            return attn + mlp + norms
        if kind == "moe":
            experts = self.n_experts * d * self.d_ff * 3
            shared = self.n_shared_experts * d * self.d_ff * 3
            router = d * self.n_experts
            return attn + experts + shared + router + norms
        if kind == "ssm":
            di, ns, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
            # in_proj (z,x,B,C,dt) + conv + out_proj + A,D + norm
            zxbcdt = d * (2 * di + 2 * ns + nh)
            return zxbcdt + self.conv_width * (di + 2 * ns) + di * d + 2 * nh + di + d
        if kind == "rec":
            w = self.lru_dim
            # two in-proj branches, conv, RG-LRU gates, out proj + mlp + norms
            return 2 * d * w + self.conv_width * w + 2 * w * w + w + w * d + d * self.d_ff * (3 if self.mlp_gated else 2) + 2 * d
        raise ValueError(kind)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k + shared)."""
        if self.n_experts == 0:
            return self.param_count()
        n = self.param_count()
        d = self.d_model
        inactive = (self.n_experts - self.top_k) * d * self.d_ff * 3
        n -= inactive * sum(1 for k in self.layer_kinds() if k == "moe")
        return n


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) column of the assignment matrix."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a cell — weak-type
    correct, shardable, no device allocation (multi-pod dry-run deliverable)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.family == "vlm":
            specs["cross_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.cross_kv_len, cfg.d_model), jnp.bfloat16
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "vlm":
            specs["cross_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.cross_kv_len, cfg.d_model), jnp.bfloat16
            )
        return specs
    # decode: one new token against a seq_len cache (cache specs built by the
    # serving layer, see repro.models.transformer.init_cache_specs)
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "position": jax.ShapeDtypeStruct((), i32),
    }
    if cfg.family == "vlm":
        specs["cross_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.cross_kv_len, cfg.d_model), jnp.bfloat16
        )
    return specs
