"""Abstract digital-PIM machine (paper Fig 1e) and the PlaneVM gate DSL.

The machine is a set of crossbar arrays; one column-parallel logic gate
executes per cycle across *all* crossbars simultaneously.  Two gate bases are
modeled, matching the paper:

* **memristive** (MAGIC stateful logic): 2-input NOR (+ FALSE init).  Every
  gate costs ``CYCLES_PER_GATE_MEMRISTIVE = 2`` cycles (output-column
  initialization + evaluation) — this constant is what calibrates our model to
  the paper's Fig 3 numbers (9-gate full adder → 18 cycles/bit → 233 TOPS for
  32-bit fixed add on the 48 GB memristive configuration).
* **dram** (SIMDRAM-style): MAJ3/NOT via triple-row activation.  The paper
  applies identical schedule lengths with a different clock (its DRAM numbers
  are exactly the memristive ones scaled by 0.5 MHz / 333 MHz), and we follow
  that convention; see ``costmodel.py``.

``PlaneVM`` is the single source of truth for arithmetic algorithms: the same
algorithm code runs in

* **execute** mode — planes are packed ``uint32`` jnp arrays; bitwise ops give
  a bit-exact simulation (the oracle used by tests and benchmarks), while gate
  and cycle counters accumulate the analytical cost; and
* **record** mode — planes are symbolic column ids; the VM emits a flat NOR
  ``Schedule`` that the Pallas kernel (``repro.kernels.pim_bitserial``)
  executes inside VMEM tiles.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .bitplanes import UMAX

CYCLES_PER_GATE_MEMRISTIVE = 2  # MAGIC: init + evaluate
CYCLES_PER_GATE_DRAM = 2  # SIMDRAM AAP pair (paper's clock-scaled parity)

# Schedule opcodes (NOR-only basis; INIT0/INIT1 are column initializations).
OP_NOR = 0
OP_INIT0 = 1
OP_INIT1 = 2
OP_COPY = 3  # buffered copy (2 NOTs fused); costs one gate slot


@dataclasses.dataclass
class Schedule:
    """A flat column-op program: one row per gate, ``(op, a, b, out)``."""

    ops: np.ndarray  # [G, 4] int32
    num_cols: int
    input_cols: dict[str, list[int]]
    output_cols: dict[str, list[int]]

    @property
    def num_gates(self) -> int:
        return int(self.ops.shape[0])

    def cycles(self, cycles_per_gate: int = CYCLES_PER_GATE_MEMRISTIVE) -> int:
        return self.num_gates * cycles_per_gate

    def as_arrays(self):
        return (
            jnp.asarray(self.ops[:, 0], jnp.int32),
            jnp.asarray(self.ops[:, 1], jnp.int32),
            jnp.asarray(self.ops[:, 2], jnp.int32),
            jnp.asarray(self.ops[:, 3], jnp.int32),
        )


class PlaneVM:
    """Gate-level DSL over bit-planes.

    mode='execute': plane values are uint32 arrays [W]; ops evaluated eagerly.
    mode='record' : plane values are int column ids; ops appended to a program.
    """

    def __init__(self, mode: str = "execute", n_words: int | None = None):
        assert mode in ("execute", "record")
        self.mode = mode
        self.n_words = n_words
        self.gates = 0  # NOR-equivalent gate count (the paper's cost unit)
        self._not_cache: dict[int, Any] = {}
        # record mode state
        self._prog: list[tuple[int, int, int, int]] = []
        self._next_col = 0
        self._const0 = None
        self._const1 = None

    # ---------------------------------------------------------------- helpers
    def _fresh_col(self) -> int:
        c = self._next_col
        self._next_col += 1
        return c

    def input_plane(self, value=None) -> Any:
        """Declare an input plane (record mode allocates a column id)."""
        if self.mode == "record":
            return self._fresh_col()
        assert value is not None
        return jnp.asarray(value, jnp.uint32)

    def const0(self) -> Any:
        if self.mode == "execute":
            if self._const0 is None:
                self._const0 = jnp.zeros((self.n_words,), jnp.uint32)
            return self._const0
        if self._const0 is None:
            self._const0 = self._fresh_col()
            self._prog.append((OP_INIT0, 0, 0, self._const0))
        return self._const0

    def const1(self) -> Any:
        if self.mode == "execute":
            if self._const1 is None:
                self._const1 = jnp.full((self.n_words,), UMAX, jnp.uint32)
            return self._const1
        if self._const1 is None:
            self._const1 = self._fresh_col()
            self._prog.append((OP_INIT1, 0, 0, self._const1))
        return self._const1

    # ------------------------------------------------------------ gate basis
    def nor(self, a, b) -> Any:
        """The primitive gate: 1 gate slot."""
        self.gates += 1
        if self.mode == "execute":
            return ~(a | b) & UMAX
        out = self._fresh_col()
        self._prog.append((OP_NOR, a, b, out))
        return out

    def not_(self, a) -> Any:
        # Execute mode keys on id(); hold a reference to the key object so a
        # GC'd array can never alias a live cache entry via id reuse.
        key = id(a) if self.mode == "execute" else a
        hit = self._not_cache.get(key)
        if hit is not None:
            return hit[1]
        out = self.nor(a, a)
        self._not_cache[key] = (a, out)
        return out

    def or_(self, a, b) -> Any:
        return self.not_(self.nor(a, b))

    def and_(self, a, b) -> Any:
        return self.nor(self.not_(a), self.not_(b))

    def nand(self, a, b) -> Any:
        return self.not_(self.and_(a, b))

    def xnor(self, a, b) -> Any:
        n1 = self.nor(a, b)
        n2 = self.nor(a, n1)
        n3 = self.nor(b, n1)
        return self.nor(n2, n3)

    def xor(self, a, b) -> Any:
        return self.not_(self.xnor(a, b))

    def mux(self, s, x, y) -> Any:
        """s ? x : y == (s AND x) OR (~s AND y)."""
        sx = self.and_(s, x)
        sy = self.and_(self.not_(s), y)
        return self.or_(sx, sy)

    def full_adder(self, a, b, c) -> tuple[Any, Any]:
        """The 9-NOR full adder (paper §3: 9 gates/bit).  Returns (sum, carry)."""
        n1 = self.nor(a, b)
        n2 = self.nor(a, n1)
        n3 = self.nor(b, n1)
        n4 = self.nor(n2, n3)  # XNOR(a, b)
        n5 = self.nor(n4, c)  # (a^b) & ~c
        n6 = self.nor(n5, n1)  # carry = MAJ(a, b, c)
        n7 = self.nor(n4, n5)  # (a^b) & c
        n8 = self.nor(c, n5)
        n9 = self.nor(n7, n8)  # sum = a ^ b ^ c
        return n9, n6

    def half_adder(self, a, b) -> tuple[Any, Any]:
        s = self.xor(a, b)  # 5 gates
        c = self.and_(a, b)  # <=3 gates (NOTs may be cached)
        return s, c

    # ------------------------------------------------------- tree reductions
    def or_tree(self, xs: Sequence[Any]) -> Any:
        xs = list(xs)
        assert xs
        while len(xs) > 1:
            nxt = []
            for i in range(0, len(xs) - 1, 2):
                nxt.append(self.or_(xs[i], xs[i + 1]))
            if len(xs) % 2:
                nxt.append(xs[-1])
            xs = nxt
        return xs[0]

    def nor_tree(self, xs: Sequence[Any]) -> Any:
        """NOT(OR(xs)) — one gate cheaper at the root."""
        xs = list(xs)
        if len(xs) == 1:
            return self.not_(xs[0])
        while len(xs) > 2:
            nxt = []
            for i in range(0, len(xs) - 1, 2):
                nxt.append(self.or_(xs[i], xs[i + 1]))
            if len(xs) % 2:
                nxt.append(xs[-1])
            xs = nxt
        return self.nor(xs[0], xs[1])

    # ------------------------------------------------------------- recording
    def finish_schedule(self, inputs: dict[str, list[int]], outputs: dict[str, list[int]]) -> Schedule:
        assert self.mode == "record"
        ops = np.asarray(self._prog, dtype=np.int32).reshape(-1, 4)
        return Schedule(ops=ops, num_cols=self._next_col, input_cols=inputs, output_cols=outputs)


def compress_schedule(schedule: Schedule) -> Schedule:
    """Liveness-based column reallocation.

    The crossbar has a fixed column budget (1024 in the paper's memristive
    config) shared by operands, results and intermediates, so a faithful
    schedule must recycle columns.  Linear-scan allocation over last-use
    indices; output columns are pinned after their final write.
    """
    ops = schedule.ops
    n_gates = ops.shape[0]
    last_use: dict[int, int] = {}
    for g in range(n_gates):
        op, a, b, out = ops[g]
        if op == OP_NOR:
            last_use[int(a)] = g
            last_use[int(b)] = g
    protected = set()
    for cols in schedule.output_cols.values():
        protected.update(cols)
    for c in protected:
        last_use[c] = n_gates + 1  # never freed

    mapping: dict[int, int] = {}
    free: list[int] = []
    next_col = 0

    def alloc(c: int) -> int:
        nonlocal next_col
        if c in mapping:
            return mapping[c]
        if free:
            slot = free.pop()
        else:
            slot = next_col
            next_col += 1
        mapping[c] = slot
        return slot

    # inputs are live from the start
    for cols in schedule.input_cols.values():
        for c in cols:
            alloc(c)

    new_ops = np.zeros_like(ops)
    for g in range(n_gates):
        op, a, b, out = (int(x) for x in ops[g])
        na = mapping.get(a, 0) if op == OP_NOR else 0
        nb = mapping.get(b, 0) if op == OP_NOR else 0
        nout = alloc(out)
        new_ops[g] = (op, na, nb, nout)
        if op == OP_NOR:
            for c in (a, b):
                if last_use.get(c, -1) == g and c in mapping and c not in protected:
                    free.append(mapping.pop(c))

    # Input columns were allocated first, in order, before any frees — their
    # initial slots are 0..n_in-1 in declaration order.
    new_inputs = {}
    nxt = 0
    for k, cols in schedule.input_cols.items():
        new_inputs[k] = list(range(nxt, nxt + len(cols)))
        nxt += len(cols)

    return Schedule(
        ops=new_ops,
        num_cols=next_col,
        input_cols=new_inputs,
        output_cols={k: [mapping[c] for c in v] for k, v in schedule.output_cols.items()},
    )


def execute_schedule(schedule: Schedule, input_planes: dict[str, list[jnp.ndarray]], n_words: int):
    """Reference (pure-jnp, scan-based) executor for a recorded NOR program.

    State: [num_cols, n_words] uint32.  Each step applies one column op with
    dynamic indexing — compile time is O(1) in schedule length.
    """
    state = jnp.zeros((schedule.num_cols, n_words), jnp.uint32)
    for name, cols in schedule.input_cols.items():
        planes = input_planes[name]
        assert len(planes) == len(cols), (name, len(planes), len(cols))
        state = state.at[jnp.asarray(cols)].set(jnp.stack(planes))

    op, a, b, out = schedule.as_arrays()

    def step(state, g):
        op_g, a_g, b_g, out_g = g
        va = state[a_g]
        vb = state[b_g]
        nor = ~(va | vb) & UMAX
        res = jnp.where(op_g == OP_NOR, nor,
              jnp.where(op_g == OP_INIT0, jnp.zeros_like(nor),
              jnp.where(op_g == OP_INIT1, jnp.full_like(nor, UMAX), va)))
        state = state.at[out_g].set(res)
        return state, None

    state, _ = jax.lax.scan(step, state, (op, a, b, out))
    result = {}
    for name, cols in schedule.output_cols.items():
        result[name] = [state[c] for c in cols]
    return result
