"""Abstract digital-PIM machine (paper Fig 1e) and the PlaneVM gate DSL.

The machine is a set of crossbar arrays; one column-parallel logic gate
executes per cycle across *all* crossbars simultaneously.  Two gate bases are
modeled, matching the paper:

* **memristive** (MAGIC stateful logic): 2-input NOR (+ FALSE init).  Every
  gate costs ``CYCLES_PER_GATE_MEMRISTIVE = 2`` cycles (output-column
  initialization + evaluation) — this constant is what calibrates our model to
  the paper's Fig 3 numbers (9-gate full adder → 18 cycles/bit → 233 TOPS for
  32-bit fixed add on the 48 GB memristive configuration).
* **dram** (SIMDRAM-style): MAJ3/NOT via triple-row activation.  The paper
  applies identical schedule lengths with a different clock (its DRAM numbers
  are exactly the memristive ones scaled by 0.5 MHz / 333 MHz).  That
  clock-scaling convention is **retired**: the ``dram`` :class:`LogicBasis`
  now lowers NOR schedules to genuine MAJ3/NOT programs (``ir.lower_to_dram``)
  and costs them in row commands — each MAJ3 is 3 operand-copy AAPs + 1
  triple-row activation + 1 result AAP, each NOT 2 AAPs through the
  dual-contact rows — so DRAM gate counts, cycles and peak rows are
  independently derived rather than scaled memristive numbers.

``PlaneVM`` is the single source of truth for arithmetic algorithms: the same
algorithm code runs in

* **execute** mode — planes are packed ``uint32`` jnp arrays; bitwise ops give
  a bit-exact simulation (the oracle used by tests and benchmarks), while gate
  and cycle counters accumulate the analytical cost; and
* **record** mode — planes are symbolic column ids; the VM emits a flat NOR
  ``Schedule``.  Recorded schedules are SSA (every gate writes a fresh
  column) and feed the compiler pipeline in ``repro.core.ir`` — optimization
  passes, liveness column allocation, and the executor backends (interpreter
  / Pallas / analytical cost).  See DESIGN.md §3–4.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax.numpy as jnp
import numpy as np

from .bitplanes import UMAX

CYCLES_PER_GATE_MEMRISTIVE = 2  # MAGIC: init + evaluate
CYCLES_PER_GATE_DRAM = 2  # retired clock-scaled parity; kept for comparisons

# Schedule opcodes.  Rows are ``(op, a, b, c, out)``; NOR reads (a, b), MAJ3
# reads (a, b, c), NOT/COPY read (a), INIT0/INIT1 read nothing.
OP_NOR = 0
OP_INIT0 = 1
OP_INIT1 = 2
OP_COPY = 3  # buffered copy (2 NOTs fused / 1 AAP); costs one gate slot
OP_NOT = 4  # dram-native inversion (dual-contact row AAP pair)
OP_MAJ3 = 5  # dram-native 3-input majority (triple-row activation)

OP_WIDTH = 5  # columns per schedule row


def operand_slots(op: int) -> tuple[int, ...]:
    """Which of the (a, b, c) fields an opcode actually reads (0-indexed)."""
    if op == OP_NOR:
        return (0, 1)
    if op == OP_MAJ3:
        return (0, 1, 2)
    if op in (OP_COPY, OP_NOT):
        return (0,)
    return ()


def widen_ops(ops: np.ndarray) -> np.ndarray:
    """Normalize an op array to the 5-column ``(op, a, b, c, out)`` layout.

    Legacy 4-column ``(op, a, b, out)`` rows (NOR-basis only) get a zero
    ``c`` operand spliced in before the output column."""
    ops = np.asarray(ops, dtype=np.int32)
    if ops.size == 0:
        return ops.reshape(-1, OP_WIDTH)
    if ops.shape[1] == OP_WIDTH:
        return ops
    assert ops.shape[1] == 4, ops.shape
    wide = np.zeros((ops.shape[0], OP_WIDTH), dtype=np.int32)
    wide[:, :3] = ops[:, :3]
    wide[:, 4] = ops[:, 3]
    return wide


@dataclasses.dataclass(frozen=True)
class LogicBasis:
    """One digital-PIM gate basis: which opcodes are native logic gates and
    what each schedule row costs in that technology's command cycles.

    * ``memristive`` — MAGIC stateful logic: NOR is the native gate; every
      row costs 2 cycles (output-column FALSE init + evaluation).
    * ``dram`` — SIMDRAM-style triple-row activation: MAJ3/NOT are native.
      Operands must be copied into the reserved compute-row group before a
      TRA destroys them, so a MAJ3 row costs 3 operand AAPs + 1 TRA + 1
      result AAP; NOT costs 2 AAPs (through a dual-contact row); COPY/INIT
      are single AAPs from a source/reserved-constant row.
    """

    name: str
    gate_opcodes: frozenset[int]  # rows counted as native logic gates
    op_cycles: tuple[tuple[int, int], ...]  # opcode -> row-command cycles
    compute_rows: int = 0  # reserved rows (TRA group, DCC pair, constants)

    def cycles_for(self, op: int) -> int:
        return dict(self.op_cycles)[op]

    def schedule_cycles(self, ops: np.ndarray) -> int:
        """Total command cycles of a compiled op array under this basis."""
        ops = widen_ops(ops)
        table = dict(self.op_cycles)
        codes, counts = np.unique(ops[:, 0], return_counts=True)
        return int(sum(table[int(c)] * int(n) for c, n in zip(codes, counts)))

    def gate_count(self, ops: np.ndarray) -> int:
        ops = widen_ops(ops)
        return int(np.isin(ops[:, 0], list(self.gate_opcodes)).sum())


MEMRISTIVE_BASIS = LogicBasis(
    name="memristive",
    gate_opcodes=frozenset({OP_NOR}),
    op_cycles=(
        (OP_NOR, CYCLES_PER_GATE_MEMRISTIVE),
        (OP_INIT0, CYCLES_PER_GATE_MEMRISTIVE),
        (OP_INIT1, CYCLES_PER_GATE_MEMRISTIVE),
        (OP_COPY, CYCLES_PER_GATE_MEMRISTIVE),
    ),
    compute_rows=0,
)

DRAM_BASIS = LogicBasis(
    name="dram",
    gate_opcodes=frozenset({OP_MAJ3, OP_NOT}),
    op_cycles=(
        (OP_MAJ3, 5),  # 3 operand-copy AAPs + 1 TRA + 1 result AAP
        (OP_NOT, 2),  # AAP into the DCC row + negated AAP out
        (OP_COPY, 1),  # single AAP
        (OP_INIT0, 1),  # AAP from the reserved all-zeros row
        (OP_INIT1, 1),  # AAP from the reserved all-ones row
    ),
    # 3 TRA compute rows + 2 dual-contact rows + all-0/all-1 constant rows:
    # the subset of SIMDRAM's reserved B-group our opcodes need.
    compute_rows=7,
)

BASES: dict[str, LogicBasis] = {b.name: b for b in (MEMRISTIVE_BASIS, DRAM_BASIS)}


def get_basis(basis: str | LogicBasis) -> LogicBasis:
    if isinstance(basis, LogicBasis):
        return basis
    return BASES[basis]


@dataclasses.dataclass
class Schedule:
    """A flat column-op program: one row per gate, ``(op, a, b, c, out)``.

    Legacy 4-column ``(op, a, b, out)`` arrays are widened on construction."""

    ops: np.ndarray  # [G, 5] int32
    num_cols: int
    input_cols: dict[str, list[int]]
    output_cols: dict[str, list[int]]

    def __post_init__(self):
        self.ops = widen_ops(self.ops)

    @property
    def num_gates(self) -> int:
        return int(self.ops.shape[0])

    def cycles(self, cycles_per_gate: int = CYCLES_PER_GATE_MEMRISTIVE) -> int:
        return self.num_gates * cycles_per_gate

    def as_arrays(self):
        return tuple(
            jnp.asarray(self.ops[:, j], jnp.int32) for j in range(OP_WIDTH)
        )


class PlaneVM:
    """Gate-level DSL over bit-planes.

    mode='execute': plane values are uint32 arrays [W]; ops evaluated eagerly.
    mode='record' : plane values are int column ids; ops appended to a program.
    """

    def __init__(self, mode: str = "execute", n_words: int | None = None):
        assert mode in ("execute", "record")
        self.mode = mode
        self.n_words = n_words
        self.gates = 0  # NOR-equivalent gate count (the paper's cost unit)
        self._not_cache: dict[int, Any] = {}
        # record mode state (rows are (op, a, b, c, out))
        self._prog: list[tuple[int, int, int, int, int]] = []
        self._next_col = 0
        self._const0 = None
        self._const1 = None

    # ---------------------------------------------------------------- helpers
    def _fresh_col(self) -> int:
        c = self._next_col
        self._next_col += 1
        return c

    def input_plane(self, value=None) -> Any:
        """Declare an input plane (record mode allocates a column id)."""
        if self.mode == "record":
            return self._fresh_col()
        assert value is not None
        return jnp.asarray(value, jnp.uint32)

    def const0(self) -> Any:
        if self.mode == "execute":
            if self._const0 is None:
                self._const0 = jnp.zeros((self.n_words,), jnp.uint32)
            return self._const0
        if self._const0 is None:
            self._const0 = self._fresh_col()
            self._prog.append((OP_INIT0, 0, 0, 0, self._const0))
        return self._const0

    def const1(self) -> Any:
        if self.mode == "execute":
            if self._const1 is None:
                self._const1 = jnp.full((self.n_words,), UMAX, jnp.uint32)
            return self._const1
        if self._const1 is None:
            self._const1 = self._fresh_col()
            self._prog.append((OP_INIT1, 0, 0, 0, self._const1))
        return self._const1

    # ------------------------------------------------------------ gate basis
    def nor(self, a, b) -> Any:
        """The primitive memristive gate: 1 gate slot."""
        self.gates += 1
        if self.mode == "execute":
            return ~(a | b) & UMAX
        out = self._fresh_col()
        self._prog.append((OP_NOR, a, b, 0, out))
        return out

    def maj3(self, a, b, c) -> Any:
        """3-input majority — the dram basis' native gate (1 gate slot)."""
        self.gates += 1
        if self.mode == "execute":
            return ((a & b) | (a & c) | (b & c)) & UMAX
        out = self._fresh_col()
        self._prog.append((OP_MAJ3, a, b, c, out))
        return out

    def not_(self, a) -> Any:
        # Execute mode keys on id(); hold a reference to the key object so a
        # GC'd array can never alias a live cache entry via id reuse.
        key = id(a) if self.mode == "execute" else a
        hit = self._not_cache.get(key)
        if hit is not None:
            return hit[1]
        out = self.nor(a, a)
        self._not_cache[key] = (a, out)
        return out

    def or_(self, a, b) -> Any:
        return self.not_(self.nor(a, b))

    def and_(self, a, b) -> Any:
        return self.nor(self.not_(a), self.not_(b))

    def nand(self, a, b) -> Any:
        return self.not_(self.and_(a, b))

    def xnor(self, a, b) -> Any:
        n1 = self.nor(a, b)
        n2 = self.nor(a, n1)
        n3 = self.nor(b, n1)
        return self.nor(n2, n3)

    def xor(self, a, b) -> Any:
        return self.not_(self.xnor(a, b))

    def mux(self, s, x, y) -> Any:
        """s ? x : y == (s AND x) OR (~s AND y)."""
        sx = self.and_(s, x)
        sy = self.and_(self.not_(s), y)
        return self.or_(sx, sy)

    def full_adder(self, a, b, c) -> tuple[Any, Any]:
        """The 9-NOR full adder (paper §3: 9 gates/bit).  Returns (sum, carry)."""
        n1 = self.nor(a, b)
        n2 = self.nor(a, n1)
        n3 = self.nor(b, n1)
        n4 = self.nor(n2, n3)  # XNOR(a, b)
        n5 = self.nor(n4, c)  # (a^b) & ~c
        n6 = self.nor(n5, n1)  # carry = MAJ(a, b, c)
        n7 = self.nor(n4, n5)  # (a^b) & c
        n8 = self.nor(c, n5)
        n9 = self.nor(n7, n8)  # sum = a ^ b ^ c
        return n9, n6

    def half_adder(self, a, b) -> tuple[Any, Any]:
        s = self.xor(a, b)  # 5 gates
        c = self.and_(a, b)  # <=3 gates (NOTs may be cached)
        return s, c

    # ------------------------------------------------------- tree reductions
    def or_tree(self, xs: Sequence[Any]) -> Any:
        xs = list(xs)
        assert xs
        while len(xs) > 1:
            nxt = []
            for i in range(0, len(xs) - 1, 2):
                nxt.append(self.or_(xs[i], xs[i + 1]))
            if len(xs) % 2:
                nxt.append(xs[-1])
            xs = nxt
        return xs[0]

    def nor_tree(self, xs: Sequence[Any]) -> Any:
        """NOT(OR(xs)) — one gate cheaper at the root."""
        xs = list(xs)
        if len(xs) == 1:
            return self.not_(xs[0])
        while len(xs) > 2:
            nxt = []
            for i in range(0, len(xs) - 1, 2):
                nxt.append(self.or_(xs[i], xs[i + 1]))
            if len(xs) % 2:
                nxt.append(xs[-1])
            xs = nxt
        return self.nor(xs[0], xs[1])

    # ------------------------------------------------------------- recording
    def finish_schedule(self, inputs: dict[str, list[int]], outputs: dict[str, list[int]]) -> Schedule:
        assert self.mode == "record"
        ops = np.asarray(self._prog, dtype=np.int32).reshape(-1, OP_WIDTH)
        return Schedule(ops=ops, num_cols=self._next_col, input_cols=inputs, output_cols=outputs)


def compress_schedule(schedule: Schedule) -> Schedule:
    """Deprecated compat wrapper over ``ir.lower`` (liveness column allocation).

    The crossbar has a fixed column budget (1024 in the paper's memristive
    config) shared by operands, results and intermediates, so a faithful
    schedule must recycle columns.  The actual linear-scan allocator lives in
    :mod:`repro.core.ir` as the lowering stage of the compiler pipeline; call
    ``ir.lower(ir.from_schedule(schedule))`` directly instead.
    """
    import warnings

    from . import ir

    warnings.warn(
        "machine.compress_schedule is deprecated; use "
        "ir.lower(ir.from_schedule(schedule)) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    return ir.lower(ir.from_schedule(schedule)).to_schedule()


def execute_schedule(schedule: Schedule, input_planes: dict[str, list[jnp.ndarray]], n_words: int):
    """Reference (pure-jnp, scan-based) executor for a recorded NOR program.

    Named-dict compat wrapper over the ``interpreter`` backend in
    :mod:`repro.core.ir` — state is [num_cols, n_words] uint32 and each scan
    step applies one column op with dynamic indexing, so compile time is
    O(1) in schedule length.
    """
    from . import ir

    return ir.execute_named(schedule, input_planes, n_words)
