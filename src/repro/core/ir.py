"""Schedule IR: the single compilation artifact between recording and execution.

The paper's cost unit is a *serial NOR-gate schedule* (one column-parallel
gate per cycle).  This module turns the recorded schedule into a real
compiler pipeline (DESIGN.md §3–4):

    PlaneVM record  →  ScheduleIR (SSA)  →  optimization passes  →
    lower (liveness column allocation)  →  CompiledSchedule  →  backend

``ScheduleIR`` is in SSA form: every row ``(op, a, b, out)`` defines a fresh
value id, so passes are simple forward/backward rewrites with a substitution
map.  ``lower`` maps values onto physical crossbar columns with linear-scan
liveness recycling (this absorbs and retires the old
``machine.compress_schedule``) and produces a ``CompiledSchedule`` with
static input/output slot maps.

The pipeline is **basis-parameterized** (``machine.LogicBasis``): ops are
recorded once in the memristive NOR basis, and ``lower_to_dram`` rewrites the
SSA program into the DRAM basis' native MAJ3/NOT gates via majority
identities — the 9-NOR full adder becomes the textbook 3-MAJ/2-NOT form, so
ripple adders never pay the naive per-NOR expansion.  All passes and the
allocator are basis-aware, and per-basis costs (row-command cycles, peak
rows including the reserved DRAM compute rows) replace the old clock-scaled
parity.

The compilation unit is a multi-op :class:`Program` (``compile_program``):
per-op ``aritpim`` netlists are recorded into **one** SSA program with the
output values of each op wired directly into the next, so intermediate
planes never materialize in HBM and fold/cse/fuse/dce plus the liveness
allocator all fire across op boundaries.  ``compile_op`` is the one-op
special case (``Program.single``), sharing the same cache.  Programs are
built by the ``repro.pim`` trace-and-compile frontend.

Beyond the rewrite passes, two *scheduling* passes reorder gates without
changing the DAG: ``levelize`` partitions the program into dependency waves
(mutually independent gates — the paper's intra-array parallelism metric,
``CostReport.parallel_cycles``) and ``reorder`` is a register-pressure-aware
list scheduler that shortens live ranges before the linear-scan allocator,
cutting ``num_cols``/``peak_rows`` (never increasing them — DESIGN.md §5).

Executor backends share one interface (``Backend.run``) and live in a
registry: ``interpreter`` (pure-jnp scan), ``pallas`` / ``pallas-unrolled``
/ ``pallas-loop`` (the TPU kernels in ``repro.kernels.pim_bitserial``,
registered lazily) and ``cost`` (analytical gate/cycle model — no data
movement at all).  Compiled schedules are cached
by ``(program, basis, pass_list)`` so every consumer (``kernels.ops``,
``core.simulate``, ``core.analyzer``, benchmarks) pulls from one path.

Registering a new op = one entry in ``aritpim._OP_TABLE``; a new backend =
one ``register_backend`` call.  See DESIGN.md §4 and README.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .bitplanes import UMAX
from .machine import (
    CYCLES_PER_GATE_MEMRISTIVE,
    OP_COPY,
    OP_INIT0,
    OP_INIT1,
    OP_MAJ3,
    OP_NOR,
    OP_NOT,
    OP_WIDTH,
    LogicBasis,
    Schedule,
    get_basis,
    operand_slots,
    widen_ops,
)

# ---------------------------------------------------------------------------
# IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScheduleIR:
    """SSA gate program: each row defines value ``out`` exactly once."""

    ops: np.ndarray  # [G, 5] int32 (op, a, b, c, out)
    num_values: int
    inputs: dict[str, list[int]]  # name -> value ids (declaration order)
    outputs: dict[str, list[int]]  # name -> value ids
    meta: dict = dataclasses.field(default_factory=dict)
    pass_log: tuple[str, ...] = ()

    @property
    def num_gates(self) -> int:
        return int(self.ops.shape[0])

    @property
    def nor_gates(self) -> int:
        """Rows that are NOR gates — the paper's compute-complexity unit."""
        return int((self.ops[:, 0] == OP_NOR).sum())

    @property
    def maj_gates(self) -> int:
        return int((self.ops[:, 0] == OP_MAJ3).sum())

    def gate_count(self, basis: str | LogicBasis) -> int:
        """Rows that are native logic gates under ``basis``."""
        return get_basis(basis).gate_count(self.ops)


def _row_operands(op: int, a: int, b: int, c: int) -> tuple[int, ...]:
    """Value ids a row actually reads (opcode-dependent arity)."""
    return tuple((a, b, c)[s] for s in operand_slots(op))


def from_schedule(schedule: Schedule) -> ScheduleIR:
    """Lift a freshly *recorded* ``machine.Schedule`` into SSA.

    Recorded schedules are SSA already (the VM allocates a fresh column per
    gate output); column-allocated schedules are not and are rejected.
    """
    ops = widen_ops(schedule.ops)
    defined = set()
    for cols in schedule.input_cols.values():
        defined.update(cols)
    for row in ops:
        out = int(row[4])
        if out in defined:
            raise ValueError(
                "schedule is not SSA (column written twice) — lift before "
                "column allocation, not after"
            )
        defined.add(out)
    return ScheduleIR(
        ops=np.array(ops, dtype=np.int32).reshape(-1, OP_WIDTH),
        num_values=schedule.num_cols,
        inputs={k: list(v) for k, v in schedule.input_cols.items()},
        outputs={k: list(v) for k, v in schedule.output_cols.items()},
    )


# ---------------------------------------------------------------------------
# Pass framework
# ---------------------------------------------------------------------------


def _resolve(subst: dict[int, int], v: int) -> int:
    while v in subst:
        v = subst[v]
    return v


def _finish(ir: ScheduleIR, gates: list[tuple[int, int, int, int, int]],
            subst: dict[int, int], name: str) -> ScheduleIR:
    """Renumber values compactly (inputs first, then kept gates in order)."""
    mapping: dict[int, int] = {}
    new_inputs = {}
    for k, cols in ir.inputs.items():
        ids = []
        for c in cols:
            mapping[c] = len(mapping)
            ids.append(mapping[c])
        new_inputs[k] = ids
    new_gates = []
    for op, a, b, c, out in gates:
        row = [op, 0, 0, 0, 0]
        for s in operand_slots(op):
            row[1 + s] = mapping[(a, b, c)[s]]
        mapping[out] = len(mapping)
        row[4] = mapping[out]
        new_gates.append(tuple(row))
    new_outputs = {
        k: [mapping[_resolve(subst, v)] for v in vs] for k, vs in ir.outputs.items()
    }
    return ScheduleIR(
        ops=np.asarray(new_gates, dtype=np.int32).reshape(-1, OP_WIDTH),
        num_values=len(mapping),
        inputs=new_inputs,
        outputs=new_outputs,
        meta=dict(ir.meta),
        pass_log=ir.pass_log + (name,),
    )


def fold_constants(ir: ScheduleIR) -> ScheduleIR:
    """INIT/constant folding, basis-aware.

    NOR: a known-1 operand gives INIT0, two known-0s give INIT1, one known-0
    canonicalizes to NOT (helps CSE).  NOT of a constant is the opposite
    INIT.  MAJ3: two constant operands decide the vote (two 1s → INIT1, two
    0s → INIT0, a 1 and a 0 → the remaining operand); two *equal* operands
    decide it too (MAJ(x, x, y) = x)."""
    subst: dict[int, int] = {}
    const: dict[int, int] = {}
    gates: list[tuple[int, int, int, int, int]] = []
    for op, a, b, c, out in ir.ops:
        op, a, b, c, out = int(op), int(a), int(b), int(c), int(out)
        if op in (OP_INIT0, OP_INIT1):
            const[out] = 0 if op == OP_INIT0 else 1
            gates.append((op, 0, 0, 0, out))
        elif op == OP_COPY:
            subst[out] = _resolve(subst, a)
        elif op == OP_NOT:
            a = _resolve(subst, a)
            ca = const.get(a)
            if ca is not None:
                const[out] = 1 - ca
                gates.append((OP_INIT0 if ca == 1 else OP_INIT1, 0, 0, 0, out))
            else:
                gates.append((OP_NOT, a, 0, 0, out))
        elif op == OP_MAJ3:
            a, b, c = (_resolve(subst, v) for v in (a, b, c))
            vals = (a, b, c)
            consts = [const.get(v) for v in vals]
            ones = consts.count(1)
            zeros = consts.count(0)
            if ones >= 2:
                const[out] = 1
                gates.append((OP_INIT1, 0, 0, 0, out))
            elif zeros >= 2:
                const[out] = 0
                gates.append((OP_INIT0, 0, 0, 0, out))
            elif ones == 1 and zeros == 1:
                # the remaining operand decides the vote
                rest = [v for v, cv in zip(vals, consts) if cv is None]
                subst[out] = rest[0]
            elif a == b or a == c:
                subst[out] = a  # MAJ(x, x, y) = x
            elif b == c:
                subst[out] = b
            else:
                gates.append((OP_MAJ3, a, b, c, out))
        else:  # OP_NOR
            a, b = _resolve(subst, a), _resolve(subst, b)
            ca, cb = const.get(a), const.get(b)
            if ca == 1 or cb == 1:
                const[out] = 0
                gates.append((OP_INIT0, 0, 0, 0, out))
            elif ca == 0 and cb == 0:
                const[out] = 1
                gates.append((OP_INIT1, 0, 0, 0, out))
            elif ca == 0:
                gates.append((OP_NOR, b, b, 0, out))
            elif cb == 0:
                gates.append((OP_NOR, a, a, 0, out))
            else:
                gates.append((OP_NOR, a, b, 0, out))
    return _finish(ir, gates, subst, "fold")


def common_subexpr_elim(ir: ScheduleIR, window: int | None = None) -> ScheduleIR:
    """Gate-level CSE by forward value numbering, basis-aware (NOR and MAJ3
    operand orders are normalized — both gates are fully commutative).

    Merging a recomputation reuses an *old* value, extending its live range —
    which can raise the peak column count the allocator must provision.
    ``window`` bounds how far back (in kept gates) a logic gate may be
    reused; ``None`` is unbounded.  ``compile_op`` tightens the window
    adaptively until the schedule fits the unoptimized column budget.
    """
    subst: dict[int, int] = {}
    seen: dict[tuple, tuple[int, int]] = {}  # key -> (value, kept index)
    gates: list[tuple[int, int, int, int, int]] = []
    for op, a, b, c, out in ir.ops:
        op, a, b, c, out = int(op), int(a), int(b), int(c), int(out)
        if op == OP_COPY:
            subst[out] = _resolve(subst, a)
            continue
        if op in (OP_INIT0, OP_INIT1):
            key = (op,)
            a = b = c = 0
        elif op == OP_NOT:
            a = _resolve(subst, a)
            b = c = 0
            key = (OP_NOT, a)
        elif op == OP_MAJ3:
            a, b, c = sorted(_resolve(subst, v) for v in (a, b, c))
            key = (OP_MAJ3, a, b, c)
        else:  # OP_NOR
            a, b = _resolve(subst, a), _resolve(subst, b)
            c = 0
            key = (OP_NOR, min(a, b), max(a, b))
        hit = seen.get(key)
        is_logic = op in (OP_NOR, OP_NOT, OP_MAJ3)
        if hit is not None and (
            not is_logic or window is None or len(gates) - hit[1] <= window
        ):
            subst[out] = hit[0]
            continue
        seen[key] = (out, len(gates))
        gates.append((op, a, b, c, out))
    return _finish(ir, gates, subst, "cse" if window is None else f"cse@{window}")


def fuse_copies(ir: ScheduleIR) -> ScheduleIR:
    """COPY/NOT fusion: COPYs are propagated away and NOT(NOT(x)) folds to x
    in either basis representation — ``NOR(v, v)`` or native ``OP_NOT`` (the
    record-mode not-cache catches most, but CSE/fold/basis-lowering expose
    more)."""
    subst: dict[int, int] = {}
    defs: dict[int, tuple] = {}
    gates: list[tuple[int, int, int, int, int]] = []

    def inverted_input(v: int) -> int | None:
        """x if value ``v`` is NOT(x) in either representation, else None."""
        d = defs.get(v)
        if d is None:
            return None
        if d[0] == OP_NOT or (d[0] == OP_NOR and d[1] == d[2]):
            return d[1]
        return None

    for op, a, b, c, out in ir.ops:
        op, a, b, c, out = int(op), int(a), int(b), int(c), int(out)
        if op == OP_COPY:
            subst[out] = _resolve(subst, a)
            continue
        if op == OP_NOR:
            a, b = _resolve(subst, a), _resolve(subst, b)
            if a == b:
                inner = inverted_input(a)
                if inner is not None:
                    subst[out] = inner  # NOT(NOT(x)) == x
                    continue
            gates.append((OP_NOR, a, b, 0, out))
            defs[out] = (OP_NOR, a, b)
        elif op == OP_NOT:
            a = _resolve(subst, a)
            inner = inverted_input(a)
            if inner is not None:
                subst[out] = inner
                continue
            gates.append((OP_NOT, a, 0, 0, out))
            defs[out] = (OP_NOT, a)
        elif op == OP_MAJ3:
            a, b, c = (_resolve(subst, v) for v in (a, b, c))
            gates.append((OP_MAJ3, a, b, c, out))
            defs[out] = (OP_MAJ3, a, b, c)
        else:
            gates.append((op, 0, 0, 0, out))
            defs[out] = (op, 0)
    return _finish(ir, gates, subst, "fuse")


def dead_gate_elim(ir: ScheduleIR) -> ScheduleIR:
    """Drop gates whose results can never reach an output plane."""
    live = {v for cols in ir.outputs.values() for v in cols}
    keep = np.zeros(ir.num_gates, dtype=bool)
    for g in range(ir.num_gates - 1, -1, -1):
        op, a, b, c, out = (int(x) for x in ir.ops[g])
        if out in live:
            keep[g] = True
            live.update(_row_operands(op, a, b, c))
    gates = [tuple(int(x) for x in row) for row in ir.ops[keep]]
    return _finish(ir, gates, {}, "dce")


# ---------------------------------------------------------------------------
# Gate scheduling: dependency waves + register-pressure-aware reordering
# ---------------------------------------------------------------------------


def _gate_rows(ir: ScheduleIR) -> list[tuple[int, int, int, int, int]]:
    return [tuple(int(x) for x in row) for row in ir.ops]


def _dataflow_waves(gates) -> list[int]:
    """1-based dependency wave per gate: ``wave = 1 + max(operand waves)``.

    Gates in the same wave are mutually independent, so a machine that can
    fire every array column-op concurrently finishes the schedule in
    ``max(waves)`` steps — the paper's intra-array gate-parallelism bound
    (``CostReport.parallel_cycles``).  Inputs sit at wave 0.  The metric is
    a DAG property: reordering passes never change it.
    """
    wave_of: dict[int, int] = {}
    waves = []
    for op, a, b, c, out in gates:
        w = 1 + max((wave_of.get(v, 0) for v in _row_operands(op, a, b, c)),
                    default=0)
        wave_of[out] = w
        waves.append(w)
    return waves


def levelize(ir: ScheduleIR) -> ScheduleIR:
    """Partition the SSA gate DAG into dependency waves and reorder the
    schedule wave-major (stable within a wave).

    The wave count is the paper's intra-array parallelism metric — it flows
    to ``CostReport.parallel_cycles`` — and wave-major order groups mutually
    independent gates contiguously, which is the layout the unrolled Pallas
    executor's read-then-write chunks like best.  Topological order is
    preserved by construction: every operand's wave is strictly smaller
    than its gate's wave.
    """
    gates = _gate_rows(ir)
    waves = _dataflow_waves(gates)
    order = sorted(range(len(gates)), key=lambda g: (waves[g], g))
    out = _finish(ir, [gates[g] for g in order], {}, "levelize")
    out.meta["num_waves"] = max(waves, default=0)
    return out


def _peak_live(gates, input_ids, protected) -> int:
    """Peak simultaneously-live values for a gate order — exactly the
    ``num_cols`` the linear-scan allocator in :func:`lower` will produce
    (inputs allocated up front, outputs pinned, operands freed after their
    last use)."""
    last_use: dict[int, int] = {}
    for g, (op, a, b, c, _out) in enumerate(gates):
        for v in _row_operands(op, a, b, c):
            last_use[v] = g
    live = set(input_ids)
    peak = len(live)
    for g, (op, a, b, c, out) in enumerate(gates):
        live.add(out)
        peak = max(peak, len(live))
        for v in _row_operands(op, a, b, c):
            if last_use.get(v, -1) == g and v in live and v not in protected:
                live.discard(v)
    return peak


REORDER_WINDOW = 256  # how far ahead of program order a freeing gate may hoist


def reorder_pressure(ir: ScheduleIR, window: int = REORDER_WINDOW) -> ScheduleIR:
    """Register-pressure-aware list scheduler (pass name ``reorder``).

    The recorded netlist order is already live-range-friendly (builders emit
    ripple structure depth-first), so global greedy schedulers lose to it;
    instead this pass *follows* program order and only hoists a ready gate
    from the next ``window`` rows when doing so strictly shrinks the live
    set now (it frees more operand columns than the one column it defines).
    The result is kept only if its allocator high-water mark
    (:func:`_peak_live`, = ``lower``'s ``num_cols``) is strictly better than
    the incoming order's — the pass can never increase peak columns.
    """
    gates = _gate_rows(ir)
    n = len(gates)
    operands = [set(_row_operands(op, a, b, c)) for op, a, b, c, _ in gates]
    defs = {g[4]: i for i, g in enumerate(gates)}
    protected = {v for cols in ir.outputs.values() for v in cols}
    input_ids = [v for cols in ir.inputs.values() for v in cols]

    uses: dict[int, int] = {}
    for ops_ in operands:
        for v in ops_:
            uses[v] = uses.get(v, 0) + 1
    consumers: dict[int, list[int]] = {}
    pending = [0] * n
    for i, ops_ in enumerate(operands):
        for v in ops_:
            if v in defs:
                consumers.setdefault(defs[v], []).append(i)
                pending[i] += 1
    ready = [pending[i] == 0 for i in range(n)]
    scheduled = [False] * n

    order: list[int] = []
    nxt = 0  # next unscheduled gate in program order
    while len(order) < n:
        while scheduled[nxt]:
            nxt += 1
        best, best_net = nxt, 0
        for i in range(nxt + 1, min(nxt + window + 1, n)):
            if scheduled[i] or not ready[i]:
                continue
            freed = sum(
                1 for v in operands[i] if uses[v] == 1 and v not in protected)
            if freed - 1 > best_net:  # frees more than the value it defines
                best, best_net = i, freed - 1
        i = best
        scheduled[i] = True
        order.append(i)
        for v in operands[i]:
            uses[v] -= 1
        for j in consumers.get(i, []):
            pending[j] -= 1
            if pending[j] == 0:
                ready[j] = True

    reordered = [gates[i] for i in order]
    if _peak_live(reordered, input_ids, protected) >= _peak_live(
            gates, input_ids, protected):
        reordered = gates  # never worse than the incoming order
    return _finish(ir, reordered, {}, "reorder")


# ---------------------------------------------------------------------------
# Basis lowering: NOR → MAJ3/NOT (the dram basis)
# ---------------------------------------------------------------------------

# The 9-NOR full adder as recorded by machine.PlaneVM.full_adder — gates are
# emitted contiguously, so the cluster can be matched by shape.  Row k's
# operands are given as indices into (x, y, cin, n1..n9) = (-3, -2, -1, 0..8).
_FA_SHAPE = (
    (-3, -2),  # n1 = NOR(a, b)
    (-3, 0),   # n2 = NOR(a, n1)
    (-2, 0),   # n3 = NOR(b, n1)
    (1, 2),    # n4 = NOR(n2, n3)
    (3, -1),   # n5 = NOR(n4, c)
    (4, 0),    # n6 = NOR(n5, n1)  -> carry
    (3, 4),    # n7 = NOR(n4, n5)
    (-1, 4),   # n8 = NOR(c, n5)
    (6, 7),    # n9 = NOR(n7, n8)  -> sum
)
# Use counts of the internal values n1..n8 *inside* the cluster: a match also
# requires they have no uses outside it (and are not outputs).
_FA_INTERNAL_USES = {0: 3, 1: 1, 2: 1, 3: 2, 4: 3, 6: 1, 7: 1}


def lower_to_dram(ir: ScheduleIR) -> ScheduleIR:
    """Rewrite a NOR-basis SSA program into the DRAM basis (MAJ3/NOT).

    Majority identities used (SIMDRAM-style, DESIGN.md §3):

    * full adder — the recorded 9-NOR cluster becomes the textbook
      majority-form adder: ``carry = MAJ(a, b, c)``, ``sum = MAJ(carry',
      MAJ(a, b, c'), c)`` — 3 MAJ + 2 NOT per bit, so ripple adders do not
      pay the naive per-NOR expansion (and CSE later merges the ``NOT
      carry`` each bit computes with the next bit's ``NOT cin``);
    * ``NOR(x', y') = MAJ(x, y, 0)`` (AND of the uninverted inputs — this is
      how the schoolbook multiplier's partial products stay 1 gate each);
    * ``NOR(x, x) = NOT(x)``;
    * generic ``NOR(x, y) = NOT(MAJ(x, y, 1))``.

    Constants needed by the identities are fresh INIT rows prepended to the
    program (CSE merges them with any recorded INITs).  The result contains
    no ``OP_NOR`` rows; outputs keep their value ids.
    """
    ops = ir.ops
    n = ir.num_gates
    out_vals = {v for cols in ir.outputs.values() for v in cols}
    uses: dict[int, int] = {}
    for g in range(n):
        op, a, b, c, _out = (int(x) for x in ops[g])
        for v in _row_operands(op, a, b, c):
            uses[v] = uses.get(v, 0) + 1

    next_val = ir.num_values

    def fresh() -> int:
        nonlocal next_val
        next_val += 1
        return next_val - 1

    consts: dict[int, int] = {}
    prepend: list[tuple[int, int, int, int, int]] = []

    def const(bit: int) -> int:
        if bit not in consts:
            cid = fresh()
            prepend.append((OP_INIT1 if bit else OP_INIT0, 0, 0, 0, cid))
            consts[bit] = cid
        return consts[bit]

    def match_fa(g: int) -> tuple[int, ...] | None:
        """If rows g..g+8 are a recorded full adder, return (x, y, cin)."""
        if g + 9 > n:
            return None
        if any(int(ops[g + k, 0]) != OP_NOR for k in range(9)):
            return None
        x, y = int(ops[g, 1]), int(ops[g, 2])
        cin = int(ops[g + 4, 2])
        nvals = [int(ops[g + k, 4]) for k in range(9)]
        env = {-3: x, -2: y, -1: cin}
        env.update(enumerate(nvals))
        for k, (ea, eb) in enumerate(_FA_SHAPE):
            if int(ops[g + k, 1]) != env[ea] or int(ops[g + k, 2]) != env[eb]:
                return None
        for k, internal in _FA_INTERNAL_USES.items():
            if uses.get(nvals[k], 0) != internal or nvals[k] in out_vals:
                return None
        return x, y, cin

    new: list[tuple[int, int, int, int, int]] = []
    defs: dict[int, tuple[int, int]] = {}  # value -> (OP_NOT, input)
    g = 0
    while g < n:
        fa = match_fa(g)
        if fa is not None:
            x, y, cin = fa
            carry, s = int(ops[g + 5, 4]), int(ops[g + 8, 4])
            cn, t, nc = fresh(), fresh(), fresh()
            new.append((OP_NOT, cin, 0, 0, cn))
            new.append((OP_MAJ3, x, y, cin, carry))
            new.append((OP_MAJ3, x, y, cn, t))
            new.append((OP_NOT, carry, 0, 0, nc))
            new.append((OP_MAJ3, nc, t, cin, s))
            defs[cn] = (OP_NOT, cin)
            defs[nc] = (OP_NOT, carry)
            g += 9
            continue
        op, a, b, c, out = (int(v) for v in ops[g])
        g += 1
        if op != OP_NOR:
            new.append((op, a, b, c, out))
            if op == OP_NOT:
                defs[out] = (OP_NOT, a)
            continue
        if a == b:
            new.append((OP_NOT, a, 0, 0, out))
            defs[out] = (OP_NOT, a)
            continue
        da, db = defs.get(a), defs.get(b)
        if da is not None and db is not None:
            # NOR(x', y') = x AND y = MAJ(x, y, 0)
            new.append((OP_MAJ3, da[1], db[1], const(0), out))
            continue
        t = fresh()
        new.append((OP_MAJ3, a, b, const(1), t))
        new.append((OP_NOT, t, 0, 0, out))
        defs[out] = (OP_NOT, t)

    lowered = ScheduleIR(
        ops=np.asarray(prepend + new, dtype=np.int32).reshape(-1, OP_WIDTH),
        num_values=next_val,
        inputs={k: list(v) for k, v in ir.inputs.items()},
        outputs={k: list(v) for k, v in ir.outputs.items()},
        meta=dict(ir.meta),
        pass_log=ir.pass_log + ("dram",),
    )
    lowered.meta["basis"] = "dram"
    return lowered


PASS_REGISTRY = {
    "fold": fold_constants,
    "cse": common_subexpr_elim,
    "fuse": fuse_copies,
    "dce": dead_gate_elim,
    "dram": lower_to_dram,
    "levelize": levelize,
    "reorder": reorder_pressure,
}

# fuse after cse exposes new common NORs, so cse runs again before dce;
# reorder runs last so the pressure scheduler sees the final gate set.
DEFAULT_PASSES: tuple[str, ...] = ("fold", "cse", "fuse", "cse", "dce",
                                   "reorder")

# Window ladder tried by compile_op until peak columns fit the unoptimized
# budget.  With CSE disabled entirely (last rung) the remaining passes only
# shrink live ranges, so the ladder always terminates.
CSE_WINDOW_LADDER: tuple[int | None, ...] = (None, 500, 200, 50, -1)


def run_passes(ir: ScheduleIR, passes: tuple[str, ...] = DEFAULT_PASSES,
               cse_window: int | None = None) -> ScheduleIR:
    """Run named passes in order.  ``cse_window`` overrides the reuse window
    of every ``cse`` pass (``-1`` disables NOR merging entirely)."""
    for name in passes:
        if name == "cse" and cse_window is not None:
            ir = common_subexpr_elim(ir, window=cse_window)
        else:
            ir = PASS_REGISTRY[name](ir)
    return ir


# ---------------------------------------------------------------------------
# Lowering: liveness-based column allocation (retires machine.compress_schedule)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompiledSchedule:
    """Column-machine program with static I/O slot maps — what backends run.

    ``num_cols`` is the linear-scan high-water mark, i.e. the peak number of
    simultaneously live crossbar columns/rows (operands + intermediates); the
    paper's memristive config budgets 1024.  ``peak_rows`` additionally
    counts the basis' reserved compute rows (the DRAM TRA/DCC/constant
    group), which backends never touch but real hardware must provision.
    """

    key: str
    ops: np.ndarray  # [G, 5] int32, columns recycled
    num_cols: int
    input_cols: dict[str, list[int]]
    output_cols: dict[str, list[int]]
    recorded_len: int  # schedule rows as recorded (pre-pass)
    recorded_gates: int  # recorded NOR count (the paper's cost unit)
    basis: str = "memristive"
    pass_log: tuple[str, ...] = ()
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def num_gates(self) -> int:
        return int(self.ops.shape[0])

    @property
    def nor_gates(self) -> int:
        return int((self.ops[:, 0] == OP_NOR).sum())

    @property
    def maj_gates(self) -> int:
        return int((self.ops[:, 0] == OP_MAJ3).sum())

    @property
    def not_gates(self) -> int:
        return int((self.ops[:, 0] == OP_NOT).sum())

    @property
    def native_gates(self) -> int:
        """Rows that are native logic gates under this schedule's basis
        (NOR for memristive; MAJ3 + NOT for dram)."""
        return get_basis(self.basis).gate_count(self.ops)

    @property
    def num_waves(self) -> int:
        """Dependency-wave count of the gate DAG — the schedule's depth if
        every independent gate fired concurrently (``parallel_cycles``)."""
        return int(self.meta.get("num_waves", 0))

    @property
    def peak_live_cols(self) -> int:
        return self.num_cols

    @property
    def peak_rows(self) -> int:
        """Allocation high-water mark + the basis' reserved compute rows."""
        return self.num_cols + get_basis(self.basis).compute_rows

    @property
    def input_slots(self) -> list[int]:
        return [c for name in sorted(self.input_cols) for c in self.input_cols[name]]

    @property
    def output_slots(self) -> list[int]:
        return [c for name in sorted(self.output_cols) for c in self.output_cols[name]]

    def cycles(self, cycles_per_gate: int | None = None) -> int:
        """Command cycles under this schedule's basis (per-opcode weights:
        AAP/TRA counts for dram, init+evaluate for memristive).  Passing an
        explicit ``cycles_per_gate`` forces the legacy uniform costing."""
        if cycles_per_gate is not None:
            return self.num_gates * cycles_per_gate
        return get_basis(self.basis).schedule_cycles(self.ops)

    def as_arrays(self):
        return tuple(
            jnp.asarray(self.ops[:, j], jnp.int32) for j in range(OP_WIDTH)
        )

    def to_schedule(self) -> Schedule:
        """Legacy ``machine.Schedule`` view (same ops/column maps)."""
        return Schedule(
            ops=self.ops,
            num_cols=self.num_cols,
            input_cols={k: list(v) for k, v in self.input_cols.items()},
            output_cols={k: list(v) for k, v in self.output_cols.items()},
        )

    @classmethod
    def from_legacy(cls, schedule: Schedule, key: str) -> "CompiledSchedule":
        """Wrap an already-column-allocated ``machine.Schedule`` as-is (no
        passes ran, so recorded == current counts)."""
        ops = widen_ops(schedule.ops)
        return cls(
            key=key,
            ops=ops,
            num_cols=schedule.num_cols,
            input_cols={k: list(v) for k, v in schedule.input_cols.items()},
            output_cols={k: list(v) for k, v in schedule.output_cols.items()},
            recorded_len=int(ops.shape[0]),
            recorded_gates=int((ops[:, 0] == OP_NOR).sum()),
        )


def lower(ir: ScheduleIR, key: str = "", basis: str | LogicBasis = "memristive",
          ) -> CompiledSchedule:
    """Linear-scan allocation of SSA values onto recycled crossbar columns.

    Inputs are allocated first (slots ``0..n_in-1`` in declaration order, the
    contract the Pallas kernel's static slot maps rely on); output values are
    pinned after their final write.  A gate's output column is allocated
    before its operands are freed, matching MAGIC's requirement that the
    output column be initialized while operands still hold their values.

    Under the ``dram`` basis the allocator also accounts for SIMDRAM's
    compute-row copies: operands are staged into the reserved TRA/DCC rows
    (``LogicBasis.compute_rows``, reported via ``peak_rows``), and the AAP
    copy traffic per opcode is already folded into the basis' cycle weights;
    ``meta["copy_aaps"]`` records the total operand/result AAPs so the cost
    model can report data movement separately from TRA compute."""
    basis = get_basis(basis)
    ops = ir.ops
    n_gates = ops.shape[0]
    last_use: dict[int, int] = {}
    for g in range(n_gates):
        op, a, b, c, _out = (int(x) for x in ops[g])
        for v in _row_operands(op, a, b, c):
            last_use[v] = g
    protected = {v for cols in ir.outputs.values() for v in cols}

    mapping: dict[int, int] = {}
    free: list[int] = []
    next_col = 0

    def alloc(v: int) -> int:
        nonlocal next_col
        if v in mapping:
            return mapping[v]
        if free:
            slot = free.pop()
        else:
            slot = next_col
            next_col += 1
        mapping[v] = slot
        return slot

    # Inputs are allocated first, in declaration order, before any frees —
    # capture their slots now, since non-output inputs are recycled later.
    input_cols = {k: [alloc(c) for c in cols] for k, cols in ir.inputs.items()}

    copy_aaps = 0
    new_ops = np.zeros((n_gates, OP_WIDTH), dtype=np.int32)
    for g in range(n_gates):
        op, a, b, c, out = (int(x) for x in ops[g])
        operands = _row_operands(op, a, b, c)
        row = [op, 0, 0, 0, 0]
        for s in operand_slots(op):
            row[1 + s] = mapping[(a, b, c)[s]]
        row[4] = alloc(out)
        new_ops[g] = row
        if op == OP_MAJ3:
            copy_aaps += len(operands) + 1  # stage into TRA rows + result out
        elif op == OP_NOT:
            copy_aaps += 2  # through the DCC row and back
        for v in operands:
            if last_use.get(v, -1) == g and v in mapping and v not in protected:
                free.append(mapping.pop(v))

    # Always recomputed here (O(G)) rather than trusted from pass meta: a
    # pass running after levelize may have changed the gate set.
    num_waves = max(_dataflow_waves(_gate_rows(ir)), default=0)
    return CompiledSchedule(
        key=key,
        ops=new_ops,
        num_cols=next_col,
        input_cols=input_cols,
        output_cols={k: [mapping[c] for c in v] for k, v in ir.outputs.items()},
        recorded_len=int(ir.meta.get("recorded_len", n_gates)),
        recorded_gates=int(ir.meta.get("recorded_gates", ir.nor_gates)),
        basis=basis.name,
        pass_log=ir.pass_log,
        meta=dict(ir.meta, copy_aaps=copy_aaps, num_waves=num_waves),
    )


# ---------------------------------------------------------------------------
# Multi-op programs: the compile_program frontend artifact
# ---------------------------------------------------------------------------


CONST_OP = "__const__"  # ProgramOp.op marker for immediate (scalar) planes


@dataclasses.dataclass(frozen=True)
class ProgramOp:
    """One traced op: an ``aritpim._OP_TABLE`` netlist applied to program
    values.  ``args`` and ``out`` are value ids — inputs are ``0..n_in-1``,
    each op defines the next id.  ``width`` is how many planes of the
    builder's result the program keeps (LSB first): fused fixed-point
    multiplies keep ``n`` of the ``2n`` product planes, and DCE then deletes
    the gates that only fed the dropped half.

    ``op == CONST_OP`` defines an immediate instead: ``imm`` holds the
    value's bit pattern (LSB-first, ``width`` planes) and recording lowers
    it to the VM's cached ``OP_INIT0``/``OP_INIT1`` constant planes — a
    traced Python scalar costs at most two INIT rows and **no** HBM input
    planes."""

    op: str
    args: tuple[int, ...]
    out: int
    width: int
    imm: int | None = None


@dataclasses.dataclass(frozen=True)
class Program:
    """A multi-op PIM program: the unit ``compile_program`` compiles.

    The per-op netlists are recorded into **one** SSA program — the output
    values of one op are wired directly into the next, so intermediate
    planes never round-trip through HBM, and fold/cse/fuse/dce and the
    liveness allocator all operate across op boundaries.  Built by the
    ``repro.pim`` tracer; ``Program.single`` wraps one table op (what
    ``compile_op`` compiles).
    """

    in_widths: tuple[int, ...]
    body: tuple[ProgramOp, ...]
    outputs: tuple[int, ...]
    name: str = "program"
    in_names: tuple[str, ...] | None = None
    out_names: tuple[str, ...] | None = None

    def input_names(self) -> tuple[str, ...]:
        """Slot names, chosen so sorted order == declaration order (the
        backend stacking contract); the 2-digit padding bounds programs at
        100 inputs — refuse loudly rather than scramble slots past it."""
        if self.in_names is not None:
            return self.in_names
        assert len(self.in_widths) <= 100, (
            "programs are limited to 100 inputs (zero-padded slot names)")
        return tuple(f"in{i:02d}" for i in range(len(self.in_widths)))

    def output_names(self) -> tuple[str, ...]:
        if self.out_names is not None:
            return self.out_names
        return tuple(f"out{j:02d}" for j in range(len(self.outputs)))

    @property
    def key(self) -> str:
        """Structural cache key: two traces of the same computation share
        one compilation regardless of the function name they came from."""
        ins = ",".join(map(str, self.in_widths))
        body = ";".join(
            f"const[{n.imm:#x}]->v{n.out}:{n.width}" if n.op == CONST_OP
            else f"{n.op}({','.join(map(str, n.args))})->v{n.out}:{n.width}"
            for n in self.body
        )
        outs = ",".join(f"v{v}" for v in self.outputs)
        names = ""
        if self.in_names is not None or self.out_names is not None:
            names = f"|names:{self.input_names()}|{self.output_names()}"
        return f"in:{ins}|{body}|out:{outs}{names}"

    @classmethod
    def single(cls, op: str, nbits: int = 32) -> "Program":
        """The one-op program ``compile_op`` is a special case of.  Keeps the
        legacy ``a``/``b``/``out`` slot names and the full builder width."""
        from . import aritpim

        spec = aritpim._OP_TABLE[op]
        wa, wb = spec.in_widths(nbits)
        return cls(
            in_widths=(wa, wb),
            body=(ProgramOp(op, (0, 1), 2, spec.out_width(nbits)),),
            outputs=(2,),
            name=f"{op}/{nbits}",
            in_names=("a", "b"),
            out_names=("out",),
        )


def record_program(program: Program) -> ScheduleIR:
    """Record a multi-op program into one SSA IR (NOR basis): per-op
    netlists are stitched value-to-value in a single ``PlaneVM``, so the
    record-mode NOT cache, constants and all downstream passes already see
    across op boundaries."""
    from . import aritpim
    from .machine import PlaneVM

    vm = PlaneVM(mode="record")
    env: dict[int, list] = {}
    inputs: dict[str, list[int]] = {}
    for i, (name, w) in enumerate(zip(program.input_names(), program.in_widths)):
        env[i] = [vm.input_plane() for _ in range(w)]
        inputs[name] = env[i]
    for node in program.body:
        if node.op == CONST_OP:
            env[node.out] = [
                vm.const1() if (node.imm >> k) & 1 else vm.const0()
                for k in range(node.width)
            ]
            continue
        spec = aritpim._OP_TABLE[node.op]
        out = list(spec.builder(vm, *[env[a] for a in node.args]))
        assert len(out) >= node.width, (node.op, len(out), node.width)
        env[node.out] = out[: node.width]
    outputs = {
        name: env[v] for name, v in zip(program.output_names(), program.outputs)
    }
    ir = from_schedule(vm.finish_schedule(inputs, outputs))
    ir.meta.update(
        program=program.key, name=program.name,
        recorded_len=ir.num_gates, recorded_gates=vm.gates,
    )
    return ir


def record_op(op: str, nbits: int = 32) -> ScheduleIR:
    """Record an ``aritpim._OP_TABLE`` builder into SSA IR (NOR basis) —
    the one-op special case of :func:`record_program`."""
    ir = record_program(Program.single(op, nbits))
    ir.meta.update(op=op, nbits=nbits)
    return ir


# ---------------------------------------------------------------------------
# Compilation cache: (program, basis, pass_list) → CompiledSchedule
# ---------------------------------------------------------------------------

_COMPILE_CACHE: dict[
    tuple[str, str, tuple[str, ...]], CompiledSchedule
] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def cache_stats() -> dict[str, int]:
    """Compile-cache hit/miss counters (reported by ``benchmarks.smoke`` so
    cache regressions are visible in CI logs)."""
    return dict(_CACHE_STATS)


def compile_program(
    program: Program,
    passes: tuple[str, ...] = DEFAULT_PASSES,
    basis: str | LogicBasis = "memristive",
) -> CompiledSchedule:
    """Record → basis-lower → optimize → allocate a multi-op program, cached
    by ``(program, basis, pass_list)``.

    The column-budget baseline is the *basis-lowered* program allocated with
    no optimization passes, so the CSE window ladder compares like with like
    on both bases."""
    basis = get_basis(basis)
    passes = tuple(passes)
    cache_key = (program.key, basis.name, passes)
    hit = _COMPILE_CACHE.get(cache_key)
    if hit is not None:
        _CACHE_STATS["hits"] += 1
        return hit
    _CACHE_STATS["misses"] += 1
    recorded = record_program(program)
    if basis.name == "dram":
        recorded = lower_to_dram(recorded)
        recorded.meta["prepass_gates"] = recorded.gate_count(basis)
        recorded.meta["prepass_len"] = recorded.num_gates
    baseline_cols = lower(recorded, basis=basis).num_cols
    # The schedule key must be unique per *structure* (it names jit-static
    # slot maps in the Pallas registry); the human-readable program name
    # alone could collide across different traced lambdas.
    digest = hashlib.sha1(program.key.encode()).hexdigest()[:8]
    key = (f"{program.name}@{digest}/{basis.name}/"
           f"{'+'.join(passes) if passes else 'raw'}")
    compiled = None
    for window in CSE_WINDOW_LADDER if "cse" in passes else (None,):
        optimized = run_passes(recorded, passes, cse_window=window)
        compiled = lower(optimized, key=key, basis=basis)
        if compiled.num_cols <= baseline_cols:
            break
    compiled.meta["baseline_cols"] = baseline_cols
    _COMPILE_CACHE[cache_key] = compiled
    return compiled


def compile_op(
    op: str,
    nbits: int = 32,
    passes: tuple[str, ...] = DEFAULT_PASSES,
    basis: str | LogicBasis = "memristive",
) -> CompiledSchedule:
    """Compile one ``_OP_TABLE`` op — the single-op special case of
    :func:`compile_program`, sharing its cache on both bases."""
    return compile_program(Program.single(op, nbits), passes, basis)


# ---------------------------------------------------------------------------
# Executor backends
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostReport:
    """Analytical cost of one vectored schedule execution (length-independent).

    ``gates`` counts the basis' *native* logic gates actually executed (NOR
    for memristive, MAJ3 + NOT for dram); ``cycles`` uses the basis'
    per-opcode command weights (init+evaluate pairs for MAGIC, AAP/TRA row
    commands for SIMDRAM) — DRAM numbers are independently derived, not
    clock-scaled memristive ones."""

    key: str
    gates: int  # optimized native gate count actually executed
    recorded_gates: int  # recorded NOR count (paper's unit; passes only shrink it)
    schedule_len: int  # optimized rows incl. INITs
    cycles: int  # per-basis command cycles for the whole schedule
    num_cols: int  # peak live columns (liveness high-water mark)
    parallel_cycles: int = 0  # dependency waves: intra-array parallel depth
    cycles_per_gate: int = CYCLES_PER_GATE_MEMRISTIVE
    basis: str = "memristive"
    maj_gates: int = 0  # dram basis: MAJ3 rows (the TRA count)
    not_gates: int = 0  # dram basis: NOT rows (DCC activations)
    peak_rows: int = 0  # num_cols + the basis' reserved compute rows
    copy_aaps: int = 0  # dram basis: operand/result AAP copies
    hbm_planes_in: int = 0  # input bit-planes crossing the array boundary
    hbm_planes_out: int = 0  # output bit-planes crossing the array boundary

    @property
    def hbm_planes(self) -> int:
        """Total bit-planes moved between HBM and the arrays per dispatch —
        the in-memory metric multi-op fusion shrinks: a fused program moves
        only its true inputs/outputs, never the intermediate planes."""
        return self.hbm_planes_in + self.hbm_planes_out


@dataclasses.dataclass
class ExecutionResult:
    planes: jnp.ndarray | None  # [n_outputs, W] uint32 (None for cost backend)
    cost: CostReport


class Backend:
    """One executor: turns a CompiledSchedule (+ stacked input planes) into
    output planes and/or an analytical cost report."""

    name = "base"

    def run(self, compiled: CompiledSchedule, planes: jnp.ndarray | None = None,
            **opts: Any) -> ExecutionResult:
        raise NotImplementedError

    def cost(self, compiled: CompiledSchedule,
             cycles_per_gate: int | None = None) -> CostReport:
        """Per-basis cost; pass ``cycles_per_gate`` to force legacy uniform
        per-row costing (the retired clock-scaling convention)."""
        return CostReport(
            key=compiled.key,
            gates=compiled.native_gates,
            recorded_gates=compiled.recorded_gates,
            schedule_len=compiled.num_gates,
            cycles=compiled.cycles(cycles_per_gate),
            num_cols=compiled.num_cols,
            parallel_cycles=int(compiled.meta.get("num_waves", 0)),
            cycles_per_gate=(
                cycles_per_gate if cycles_per_gate is not None
                else CYCLES_PER_GATE_MEMRISTIVE
            ),
            basis=compiled.basis,
            maj_gates=compiled.maj_gates,
            not_gates=compiled.not_gates,
            peak_rows=compiled.peak_rows,
            copy_aaps=int(compiled.meta.get("copy_aaps", 0)),
            hbm_planes_in=len(compiled.input_slots),
            hbm_planes_out=len(compiled.output_slots),
        )


class InterpreterBackend(Backend):
    """Reference executor: jnp scan over the column machine, O(1) compile in
    schedule length.  Planes are stacked ``[n_in, W]`` in sorted-name order."""

    name = "interpreter"

    def run(self, compiled, planes=None, **opts):
        assert planes is not None, "interpreter needs input planes"
        state = jnp.zeros((compiled.num_cols, planes.shape[1]), jnp.uint32)
        state = state.at[jnp.asarray(compiled.input_slots)].set(
            jnp.asarray(planes, jnp.uint32))
        op, a, b, c, out = compiled.as_arrays()

        def step(state, g):
            op_g, a_g, b_g, c_g, out_g = g
            va = state[a_g]
            vb = state[b_g]
            vc = state[c_g]
            nor = ~(va | vb) & UMAX
            maj = (va & vb) | (va & vc) | (vb & vc)
            res = jnp.where(op_g == OP_NOR, nor,
                  jnp.where(op_g == OP_MAJ3, maj,
                  jnp.where(op_g == OP_NOT, ~va & UMAX,
                  jnp.where(op_g == OP_INIT0, jnp.zeros_like(nor),
                  jnp.where(op_g == OP_INIT1, jnp.full_like(nor, UMAX), va)))))
            return state.at[out_g].set(res), None

        state, _ = jax.lax.scan(step, state, (op, a, b, c, out))
        return ExecutionResult(state[jnp.asarray(compiled.output_slots)],
                               self.cost(compiled))


class CostModelBackend(Backend):
    """Analytical backend: no data movement, just the gate/cycle bookkeeping
    that used to be duplicated across simulate.py and analyzer.py."""

    name = "cost"

    def run(self, compiled, planes=None,
            cycles_per_gate: int | None = None, **opts):
        return ExecutionResult(None, self.cost(compiled, cycles_per_gate))


_BACKENDS: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    _BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    if name not in _BACKENDS and name.startswith("pallas"):
        # The Pallas executors (pallas / pallas-unrolled / pallas-loop)
        # register themselves on import; kept lazy so core never
        # hard-depends on jax.experimental.pallas.
        import repro.kernels.pim_bitserial  # noqa: F401
    return _BACKENDS[name]


def backend_names() -> list[str]:
    return sorted(_BACKENDS)


register_backend(InterpreterBackend())
register_backend(CostModelBackend())


# ---------------------------------------------------------------------------
# Cost conveniences (consumed by simulate.py / analyzer.py / benchmarks)
# ---------------------------------------------------------------------------


def op_cost(op: str, nbits: int = 32,
            passes: tuple[str, ...] = DEFAULT_PASSES,
            basis: str | LogicBasis = "memristive") -> CostReport:
    return get_backend("cost").run(compile_op(op, nbits, passes, basis)).cost


def program_cost(program: Program,
                 passes: tuple[str, ...] = DEFAULT_PASSES,
                 basis: str | LogicBasis = "memristive") -> CostReport:
    """Program-level analytical cost (the multi-op analogue of ``op_cost``)."""
    return get_backend("cost").run(compile_program(program, passes, basis)).cost


def netlist_gate_counts(nbits: int = 32) -> dict[str, int]:
    """Recorded NOR counts for the Fig-3 op set, keyed like PAPER_GATE_COUNTS
    (plus the sub/div and bf16 entries the paper doesn't calibrate).

    The single compilation path replacing ad-hoc re-recording: counts come
    from the compile cache, so benchmarks/analyzer/simulate all agree.
    """
    def g(op: str, n: int = nbits) -> int:
        return op_cost(op, n).recorded_gates

    return {
        f"fixed{nbits}_add": g("fixed_add"),
        f"fixed{nbits}_sub": g("fixed_sub"),
        f"fixed{nbits}_mul": g("fixed_mul"),
        f"fixed{nbits}_div": g("fixed_div"),
        "float32_add": g("float_add", 32),
        "float32_mul": g("float_mul", 32),
        "float32_div": g("float_div", 32),
        "bf16_add": g("bf16_add", 16),
        "bf16_mul": g("bf16_mul", 16),
    }


def execute_named(schedule: Schedule, input_planes: dict[str, list[jnp.ndarray]],
                  n_words: int) -> dict[str, list[jnp.ndarray]]:
    """Named-dict execution of a legacy ``machine.Schedule`` via the
    interpreter backend (compat shim behind ``machine.execute_schedule``)."""
    compiled = CompiledSchedule.from_legacy(schedule, key="adhoc")
    names = sorted(compiled.input_cols)
    stacked = []
    for name in names:
        planes = input_planes[name]
        assert len(planes) == len(compiled.input_cols[name]), (
            name, len(planes), len(compiled.input_cols[name]))
        for p in planes:
            p = jnp.asarray(p, jnp.uint32)
            assert p.shape == (n_words,), (name, p.shape, n_words)
            stacked.append(p)
    out = get_backend("interpreter").run(compiled, jnp.stack(stacked)).planes
    result: dict[str, list[jnp.ndarray]] = {}
    i = 0
    for name in sorted(compiled.output_cols):
        k = len(compiled.output_cols[name])
        result[name] = [out[i + j] for j in range(k)]
        i += k
    return result
