"""Schedule IR: the single compilation artifact between recording and execution.

The paper's cost unit is a *serial NOR-gate schedule* (one column-parallel
gate per cycle).  This module turns the recorded schedule into a real
compiler pipeline (DESIGN.md §3–4):

    PlaneVM record  →  ScheduleIR (SSA)  →  optimization passes  →
    lower (liveness column allocation)  →  CompiledSchedule  →  backend

``ScheduleIR`` is in SSA form: every row ``(op, a, b, out)`` defines a fresh
value id, so passes are simple forward/backward rewrites with a substitution
map.  ``lower`` maps values onto physical crossbar columns with linear-scan
liveness recycling (this absorbs and retires the old
``machine.compress_schedule``) and produces a ``CompiledSchedule`` with
static input/output slot maps.

Executor backends share one interface (``Backend.run``) and live in a
registry: ``interpreter`` (pure-jnp scan), ``pallas`` (the TPU kernel in
``repro.kernels.pim_bitserial``, registered lazily) and ``cost`` (analytical
gate/cycle model — no data movement at all).  Compiled schedules are cached
by ``(op, nbits, pass_list)`` so every consumer (``kernels.ops``,
``core.simulate``, ``core.analyzer``, benchmarks) pulls from one path.

Registering a new op = one entry in ``aritpim._OP_TABLE``; a new backend =
one ``register_backend`` call.  See DESIGN.md §4 and README.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .bitplanes import UMAX
from .machine import (
    CYCLES_PER_GATE_MEMRISTIVE,
    OP_COPY,
    OP_INIT0,
    OP_INIT1,
    OP_NOR,
    Schedule,
)

# ---------------------------------------------------------------------------
# IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScheduleIR:
    """SSA gate program: each row defines value ``out`` exactly once."""

    ops: np.ndarray  # [G, 4] int32 (op, a, b, out)
    num_values: int
    inputs: dict[str, list[int]]  # name -> value ids (declaration order)
    outputs: dict[str, list[int]]  # name -> value ids
    meta: dict = dataclasses.field(default_factory=dict)
    pass_log: tuple[str, ...] = ()

    @property
    def num_gates(self) -> int:
        return int(self.ops.shape[0])

    @property
    def nor_gates(self) -> int:
        """Rows that are NOR gates — the paper's compute-complexity unit."""
        return int((self.ops[:, 0] == OP_NOR).sum())


def from_schedule(schedule: Schedule) -> ScheduleIR:
    """Lift a freshly *recorded* ``machine.Schedule`` into SSA.

    Recorded schedules are SSA already (the VM allocates a fresh column per
    gate output); column-allocated schedules are not and are rejected.
    """
    defined = set()
    for cols in schedule.input_cols.values():
        defined.update(cols)
    for op, _a, _b, out in schedule.ops:
        if int(out) in defined:
            raise ValueError(
                "schedule is not SSA (column written twice) — lift before "
                "column allocation, not after"
            )
        defined.add(int(out))
    return ScheduleIR(
        ops=np.array(schedule.ops, dtype=np.int32).reshape(-1, 4),
        num_values=schedule.num_cols,
        inputs={k: list(v) for k, v in schedule.input_cols.items()},
        outputs={k: list(v) for k, v in schedule.output_cols.items()},
    )


# ---------------------------------------------------------------------------
# Pass framework
# ---------------------------------------------------------------------------


def _resolve(subst: dict[int, int], v: int) -> int:
    while v in subst:
        v = subst[v]
    return v


def _finish(ir: ScheduleIR, gates: list[tuple[int, int, int, int]],
            subst: dict[int, int], name: str) -> ScheduleIR:
    """Renumber values compactly (inputs first, then kept gates in order)."""
    mapping: dict[int, int] = {}
    new_inputs = {}
    for k, cols in ir.inputs.items():
        ids = []
        for c in cols:
            mapping[c] = len(mapping)
            ids.append(mapping[c])
        new_inputs[k] = ids
    new_gates = []
    for op, a, b, out in gates:
        na = mapping[a] if op in (OP_NOR, OP_COPY) else 0
        nb = mapping[b] if op == OP_NOR else 0
        mapping[out] = len(mapping)
        new_gates.append((op, na, nb, mapping[out]))
    new_outputs = {
        k: [mapping[_resolve(subst, v)] for v in vs] for k, vs in ir.outputs.items()
    }
    return ScheduleIR(
        ops=np.asarray(new_gates, dtype=np.int32).reshape(-1, 4),
        num_values=len(mapping),
        inputs=new_inputs,
        outputs=new_outputs,
        meta=dict(ir.meta),
        pass_log=ir.pass_log + (name,),
    )


def fold_constants(ir: ScheduleIR) -> ScheduleIR:
    """INIT/constant folding: NOR with a known-1 operand is INIT0, NOR of two
    known-0s is INIT1, NOR with a known-0 canonicalizes to NOT (helps CSE)."""
    subst: dict[int, int] = {}
    const: dict[int, int] = {}
    gates: list[tuple[int, int, int, int]] = []
    for op, a, b, out in ir.ops:
        op, a, b, out = int(op), int(a), int(b), int(out)
        if op == OP_INIT0:
            const[out] = 0
            gates.append((op, 0, 0, out))
        elif op == OP_INIT1:
            const[out] = 1
            gates.append((op, 0, 0, out))
        elif op == OP_COPY:
            subst[out] = _resolve(subst, a)
        else:  # OP_NOR
            a, b = _resolve(subst, a), _resolve(subst, b)
            ca, cb = const.get(a), const.get(b)
            if ca == 1 or cb == 1:
                const[out] = 0
                gates.append((OP_INIT0, 0, 0, out))
            elif ca == 0 and cb == 0:
                const[out] = 1
                gates.append((OP_INIT1, 0, 0, out))
            elif ca == 0:
                gates.append((OP_NOR, b, b, out))
            elif cb == 0:
                gates.append((OP_NOR, a, a, out))
            else:
                gates.append((OP_NOR, a, b, out))
    return _finish(ir, gates, subst, "fold")


def common_subexpr_elim(ir: ScheduleIR, window: int | None = None) -> ScheduleIR:
    """NOR-level CSE by forward value numbering (operand order normalized).

    Merging a recomputation reuses an *old* value, extending its live range —
    which can raise the peak column count the allocator must provision.
    ``window`` bounds how far back (in kept gates) a NOR may be reused;
    ``None`` is unbounded.  ``compile_op`` tightens the window adaptively
    until the schedule fits the unoptimized column budget.
    """
    subst: dict[int, int] = {}
    seen: dict[tuple, tuple[int, int]] = {}  # key -> (value, kept index)
    gates: list[tuple[int, int, int, int]] = []
    for op, a, b, out in ir.ops:
        op, a, b, out = int(op), int(a), int(b), int(out)
        if op == OP_COPY:
            subst[out] = _resolve(subst, a)
            continue
        if op in (OP_INIT0, OP_INIT1):
            key = (op,)
            a = b = 0
        else:
            a, b = _resolve(subst, a), _resolve(subst, b)
            key = (OP_NOR, min(a, b), max(a, b))
        hit = seen.get(key)
        if hit is not None and (
            op != OP_NOR or window is None or len(gates) - hit[1] <= window
        ):
            subst[out] = hit[0]
            continue
        seen[key] = (out, len(gates))
        gates.append((op, a, b, out))
    return _finish(ir, gates, subst, "cse" if window is None else f"cse@{window}")


def fuse_copies(ir: ScheduleIR) -> ScheduleIR:
    """COPY/NOT fusion: COPYs are propagated away and NOT(NOT(x)) folds to x
    (the record-mode not-cache catches most, but CSE/fold expose more)."""
    subst: dict[int, int] = {}
    defs: dict[int, tuple[int, int, int]] = {}
    gates: list[tuple[int, int, int, int]] = []
    for op, a, b, out in ir.ops:
        op, a, b, out = int(op), int(a), int(b), int(out)
        if op == OP_COPY:
            subst[out] = _resolve(subst, a)
            continue
        if op == OP_NOR:
            a, b = _resolve(subst, a), _resolve(subst, b)
            if a == b:
                d = defs.get(a)
                if d is not None and d[0] == OP_NOR and d[1] == d[2]:
                    subst[out] = d[1]  # NOT(NOT(x)) == x
                    continue
            gates.append((OP_NOR, a, b, out))
            defs[out] = (OP_NOR, a, b)
        else:
            gates.append((op, 0, 0, out))
            defs[out] = (op, 0, 0)
    return _finish(ir, gates, subst, "fuse")


def dead_gate_elim(ir: ScheduleIR) -> ScheduleIR:
    """Drop gates whose results can never reach an output plane."""
    live = {v for cols in ir.outputs.values() for v in cols}
    keep = np.zeros(ir.num_gates, dtype=bool)
    for g in range(ir.num_gates - 1, -1, -1):
        op, a, b, out = (int(x) for x in ir.ops[g])
        if out in live:
            keep[g] = True
            if op == OP_NOR:
                live.add(a)
                live.add(b)
            elif op == OP_COPY:
                live.add(a)
    gates = [tuple(int(x) for x in row) for row in ir.ops[keep]]
    return _finish(ir, gates, {}, "dce")


PASS_REGISTRY = {
    "fold": fold_constants,
    "cse": common_subexpr_elim,
    "fuse": fuse_copies,
    "dce": dead_gate_elim,
}

# fuse after cse exposes new common NORs, so cse runs again before dce.
DEFAULT_PASSES: tuple[str, ...] = ("fold", "cse", "fuse", "cse", "dce")

# Window ladder tried by compile_op until peak columns fit the unoptimized
# budget.  With CSE disabled entirely (last rung) the remaining passes only
# shrink live ranges, so the ladder always terminates.
CSE_WINDOW_LADDER: tuple[int | None, ...] = (None, 500, 200, 50, -1)


def run_passes(ir: ScheduleIR, passes: tuple[str, ...] = DEFAULT_PASSES,
               cse_window: int | None = None) -> ScheduleIR:
    """Run named passes in order.  ``cse_window`` overrides the reuse window
    of every ``cse`` pass (``-1`` disables NOR merging entirely)."""
    for name in passes:
        if name == "cse" and cse_window is not None:
            ir = common_subexpr_elim(ir, window=cse_window)
        else:
            ir = PASS_REGISTRY[name](ir)
    return ir


# ---------------------------------------------------------------------------
# Lowering: liveness-based column allocation (retires machine.compress_schedule)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompiledSchedule:
    """Column-machine program with static I/O slot maps — what backends run.

    ``num_cols`` is the linear-scan high-water mark, i.e. the peak number of
    simultaneously live crossbar columns (operands + intermediates); the
    paper's memristive config budgets 1024.
    """

    key: str
    ops: np.ndarray  # [G, 4] int32, columns recycled
    num_cols: int
    input_cols: dict[str, list[int]]
    output_cols: dict[str, list[int]]
    recorded_len: int  # schedule rows as recorded (pre-pass)
    recorded_gates: int  # recorded NOR count (the paper's cost unit)
    pass_log: tuple[str, ...] = ()
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def num_gates(self) -> int:
        return int(self.ops.shape[0])

    @property
    def nor_gates(self) -> int:
        return int((self.ops[:, 0] == OP_NOR).sum())

    @property
    def peak_live_cols(self) -> int:
        return self.num_cols

    @property
    def input_slots(self) -> list[int]:
        return [c for name in sorted(self.input_cols) for c in self.input_cols[name]]

    @property
    def output_slots(self) -> list[int]:
        return [c for name in sorted(self.output_cols) for c in self.output_cols[name]]

    def cycles(self, cycles_per_gate: int = CYCLES_PER_GATE_MEMRISTIVE) -> int:
        return self.num_gates * cycles_per_gate

    def as_arrays(self):
        return (
            jnp.asarray(self.ops[:, 0], jnp.int32),
            jnp.asarray(self.ops[:, 1], jnp.int32),
            jnp.asarray(self.ops[:, 2], jnp.int32),
            jnp.asarray(self.ops[:, 3], jnp.int32),
        )

    def to_schedule(self) -> Schedule:
        """Legacy ``machine.Schedule`` view (same ops/column maps)."""
        return Schedule(
            ops=self.ops,
            num_cols=self.num_cols,
            input_cols={k: list(v) for k, v in self.input_cols.items()},
            output_cols={k: list(v) for k, v in self.output_cols.items()},
        )

    @classmethod
    def from_legacy(cls, schedule: Schedule, key: str) -> "CompiledSchedule":
        """Wrap an already-column-allocated ``machine.Schedule`` as-is (no
        passes ran, so recorded == current counts)."""
        ops = np.asarray(schedule.ops, np.int32).reshape(-1, 4)
        return cls(
            key=key,
            ops=ops,
            num_cols=schedule.num_cols,
            input_cols={k: list(v) for k, v in schedule.input_cols.items()},
            output_cols={k: list(v) for k, v in schedule.output_cols.items()},
            recorded_len=int(ops.shape[0]),
            recorded_gates=int((ops[:, 0] == OP_NOR).sum()),
        )


def lower(ir: ScheduleIR, key: str = "") -> CompiledSchedule:
    """Linear-scan allocation of SSA values onto recycled crossbar columns.

    Inputs are allocated first (slots ``0..n_in-1`` in declaration order, the
    contract the Pallas kernel's static slot maps rely on); output values are
    pinned after their final write.  A gate's output column is allocated
    before its operands are freed, matching MAGIC's requirement that the
    output column be initialized while operands still hold their values.
    """
    ops = ir.ops
    n_gates = ops.shape[0]
    last_use: dict[int, int] = {}
    for g in range(n_gates):
        op, a, b, _out = (int(x) for x in ops[g])
        if op == OP_NOR:
            last_use[a] = g
            last_use[b] = g
        elif op == OP_COPY:
            last_use[a] = g
    protected = {v for cols in ir.outputs.values() for v in cols}

    mapping: dict[int, int] = {}
    free: list[int] = []
    next_col = 0

    def alloc(v: int) -> int:
        nonlocal next_col
        if v in mapping:
            return mapping[v]
        if free:
            slot = free.pop()
        else:
            slot = next_col
            next_col += 1
        mapping[v] = slot
        return slot

    # Inputs are allocated first, in declaration order, before any frees —
    # capture their slots now, since non-output inputs are recycled later.
    input_cols = {k: [alloc(c) for c in cols] for k, cols in ir.inputs.items()}

    new_ops = np.zeros((n_gates, 4), dtype=np.int32)
    for g in range(n_gates):
        op, a, b, out = (int(x) for x in ops[g])
        na = mapping[a] if op in (OP_NOR, OP_COPY) else 0
        nb = mapping[b] if op == OP_NOR else 0
        nout = alloc(out)
        new_ops[g] = (op, na, nb, nout)
        operands = (a, b) if op == OP_NOR else (a,) if op == OP_COPY else ()
        for v in operands:
            if last_use.get(v, -1) == g and v in mapping and v not in protected:
                free.append(mapping.pop(v))

    return CompiledSchedule(
        key=key,
        ops=new_ops,
        num_cols=next_col,
        input_cols=input_cols,
        output_cols={k: [mapping[c] for c in v] for k, v in ir.outputs.items()},
        recorded_len=int(ir.meta.get("recorded_len", n_gates)),
        recorded_gates=int(ir.meta.get("recorded_gates", ir.nor_gates)),
        pass_log=ir.pass_log,
        meta=dict(ir.meta),
    )


# ---------------------------------------------------------------------------
# Compilation cache: (op, nbits, pass_list) → CompiledSchedule
# ---------------------------------------------------------------------------

_COMPILE_CACHE: dict[tuple[str, int, tuple[str, ...]], CompiledSchedule] = {}


def record_op(op: str, nbits: int = 32) -> ScheduleIR:
    """Record an ``aritpim._OP_TABLE`` builder into SSA IR."""
    from . import aritpim
    from .machine import PlaneVM

    fn, widths = aritpim._OP_TABLE[op]
    wa, wb = widths(nbits)
    vm = PlaneVM(mode="record")
    A = [vm.input_plane() for _ in range(wa)]
    B = [vm.input_plane() for _ in range(wb)]
    out = fn(vm, A, B)
    ir = from_schedule(vm.finish_schedule({"a": A, "b": B}, {"out": out}))
    ir.meta.update(
        op=op, nbits=nbits, recorded_len=ir.num_gates, recorded_gates=vm.gates
    )
    return ir


def compile_op(
    op: str, nbits: int = 32, passes: tuple[str, ...] = DEFAULT_PASSES
) -> CompiledSchedule:
    """Record → optimize → lower, cached by ``(op, nbits, pass_list)``."""
    passes = tuple(passes)
    cache_key = (op, nbits, passes)
    hit = _COMPILE_CACHE.get(cache_key)
    if hit is not None:
        return hit
    recorded = record_op(op, nbits)
    baseline_cols = lower(recorded).num_cols  # the old compress_schedule result
    key = f"{op}/{nbits}/{'+'.join(passes) if passes else 'raw'}"
    compiled = None
    for window in CSE_WINDOW_LADDER if "cse" in passes else (None,):
        optimized = run_passes(recorded, passes, cse_window=window)
        compiled = lower(optimized, key=key)
        if compiled.num_cols <= baseline_cols:
            break
    compiled.meta["baseline_cols"] = baseline_cols
    _COMPILE_CACHE[cache_key] = compiled
    return compiled


# ---------------------------------------------------------------------------
# Executor backends
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostReport:
    """Analytical cost of one vectored schedule execution (length-independent)."""

    key: str
    gates: int  # optimized NOR count actually executed
    recorded_gates: int  # recorded NOR count (paper's unit; passes only shrink it)
    schedule_len: int  # optimized rows incl. INITs
    cycles: int  # schedule_len * cycles_per_gate
    num_cols: int  # peak live columns
    cycles_per_gate: int = CYCLES_PER_GATE_MEMRISTIVE


@dataclasses.dataclass
class ExecutionResult:
    planes: jnp.ndarray | None  # [n_outputs, W] uint32 (None for cost backend)
    cost: CostReport


class Backend:
    """One executor: turns a CompiledSchedule (+ stacked input planes) into
    output planes and/or an analytical cost report."""

    name = "base"

    def run(self, compiled: CompiledSchedule, planes: jnp.ndarray | None = None,
            **opts: Any) -> ExecutionResult:
        raise NotImplementedError

    def cost(self, compiled: CompiledSchedule,
             cycles_per_gate: int = CYCLES_PER_GATE_MEMRISTIVE) -> CostReport:
        return CostReport(
            key=compiled.key,
            gates=compiled.nor_gates,
            recorded_gates=compiled.recorded_gates,
            schedule_len=compiled.num_gates,
            cycles=compiled.num_gates * cycles_per_gate,
            num_cols=compiled.num_cols,
            cycles_per_gate=cycles_per_gate,
        )


class InterpreterBackend(Backend):
    """Reference executor: jnp scan over the column machine, O(1) compile in
    schedule length.  Planes are stacked ``[n_in, W]`` in sorted-name order."""

    name = "interpreter"

    def run(self, compiled, planes=None, **opts):
        assert planes is not None, "interpreter needs input planes"
        state = jnp.zeros((compiled.num_cols, planes.shape[1]), jnp.uint32)
        state = state.at[jnp.asarray(compiled.input_slots)].set(
            jnp.asarray(planes, jnp.uint32))
        op, a, b, out = compiled.as_arrays()

        def step(state, g):
            op_g, a_g, b_g, out_g = g
            va = state[a_g]
            vb = state[b_g]
            nor = ~(va | vb) & UMAX
            res = jnp.where(op_g == OP_NOR, nor,
                  jnp.where(op_g == OP_INIT0, jnp.zeros_like(nor),
                  jnp.where(op_g == OP_INIT1, jnp.full_like(nor, UMAX), va)))
            return state.at[out_g].set(res), None

        state, _ = jax.lax.scan(step, state, (op, a, b, out))
        return ExecutionResult(state[jnp.asarray(compiled.output_slots)],
                               self.cost(compiled))


class CostModelBackend(Backend):
    """Analytical backend: no data movement, just the gate/cycle bookkeeping
    that used to be duplicated across simulate.py and analyzer.py."""

    name = "cost"

    def run(self, compiled, planes=None,
            cycles_per_gate: int = CYCLES_PER_GATE_MEMRISTIVE, **opts):
        return ExecutionResult(None, self.cost(compiled, cycles_per_gate))


_BACKENDS: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    _BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    if name not in _BACKENDS and name == "pallas":
        # The Pallas executor registers itself on import; kept lazy so core
        # never hard-depends on jax.experimental.pallas.
        import repro.kernels.pim_bitserial  # noqa: F401
    return _BACKENDS[name]


def backend_names() -> list[str]:
    return sorted(_BACKENDS)


register_backend(InterpreterBackend())
register_backend(CostModelBackend())


# ---------------------------------------------------------------------------
# Cost conveniences (consumed by simulate.py / analyzer.py / benchmarks)
# ---------------------------------------------------------------------------


def op_cost(op: str, nbits: int = 32,
            passes: tuple[str, ...] = DEFAULT_PASSES) -> CostReport:
    return get_backend("cost").run(compile_op(op, nbits, passes)).cost


def netlist_gate_counts(nbits: int = 32) -> dict[str, int]:
    """Recorded NOR counts for the Fig-3 op set, keyed like PAPER_GATE_COUNTS
    (plus the sub/div and bf16 entries the paper doesn't calibrate).

    The single compilation path replacing ad-hoc re-recording: counts come
    from the compile cache, so benchmarks/analyzer/simulate all agree.
    """
    def g(op: str, n: int = nbits) -> int:
        return op_cost(op, n).recorded_gates

    return {
        f"fixed{nbits}_add": g("fixed_add"),
        f"fixed{nbits}_sub": g("fixed_sub"),
        f"fixed{nbits}_mul": g("fixed_mul"),
        f"fixed{nbits}_div": g("fixed_div"),
        "float32_add": g("float_add", 32),
        "float32_mul": g("float_mul", 32),
        "float32_div": g("float_div", 32),
        "bf16_add": g("bf16_add", 16),
        "bf16_mul": g("bf16_mul", 16),
    }


def execute_named(schedule: Schedule, input_planes: dict[str, list[jnp.ndarray]],
                  n_words: int) -> dict[str, list[jnp.ndarray]]:
    """Named-dict execution of a legacy ``machine.Schedule`` via the
    interpreter backend (compat shim behind ``machine.execute_schedule``)."""
    compiled = CompiledSchedule.from_legacy(schedule, key="adhoc")
    names = sorted(compiled.input_cols)
    stacked = []
    for name in names:
        planes = input_planes[name]
        assert len(planes) == len(compiled.input_cols[name]), (
            name, len(planes), len(compiled.input_cols[name]))
        for p in planes:
            p = jnp.asarray(p, jnp.uint32)
            assert p.shape == (n_words,), (name, p.shape, n_words)
            stacked.append(p)
    out = get_backend("interpreter").run(compiled, jnp.stack(stacked)).planes
    result: dict[str, list[jnp.ndarray]] = {}
    i = 0
    for name in sorted(compiled.output_cols):
        k = len(compiled.output_cols[name])
        result[name] = [out[i + j] for j in range(k)]
        i += k
    return result
