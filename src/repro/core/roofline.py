"""Three-term roofline extraction from compiled XLA artifacts (deliverable g).

    compute term    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory term     = HLO_bytes   / (chips × HBM_bw)
    collective term = wire_bytes  / (chips × link_bw)

``cost_analysis()`` supplies FLOPs/bytes; collective bytes are parsed from the
HLO text (shapes there are already per-device after SPMD partitioning).  Wire
bytes use the standard ring-algorithm factors; the raw operand bytes are also
reported for transparency.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from .costmodel import TPU_V5E, TPUConfig

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# NB: tuple types may contain /*index=N*/ comments (hence [^()]*, not [^=]*)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>\([^()]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s*(?P<opcode>[\w\-]+)\(",
    re.M,
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_SHAPE_RE = re.compile(r"(?P<dtype>[a-z][a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")
_GROUPS_BRACED_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "u1": 1, "s1": 1,
}


def _dims_of(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group("dims").split(",") if d]


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype = m.group("dtype")
        dims = m.group("dims")
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _ring_factor(kind: str, group: int) -> float:
    """Per-device wire traffic as a multiple of the per-device payload."""
    if group <= 1:
        return 0.0
    g = float(group)
    if kind == "all-reduce":
        return 2.0 * (g - 1.0) / g
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return (g - 1.0) / g
    if kind == "collective-permute":
        return 1.0
    return 1.0


@dataclasses.dataclass
class CollectiveStats:
    operand_bytes: float = 0.0  # raw per-device operand bytes, summed
    wire_bytes: float = 0.0  # ring-model per-device wire bytes
    count: int = 0
    by_kind: dict = dataclasses.field(default_factory=lambda: defaultdict(float))


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    """Sum operand sizes of every collective op in an HLO dump (per-device)."""
    # name -> result type string (to resolve operand shapes)
    types: dict[str, str] = {}
    for m in _DEF_RE.finditer(hlo_text):
        types[m.group("name")] = m.group("type")

    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        opcode = m.group("opcode")
        kind = None
        for k in COLLECTIVE_KINDS:
            if opcode == k or opcode.startswith(k + "-") or opcode == k + "-start":
                kind = k
                break
        if kind is None:
            continue
        if opcode.endswith("-done"):
            continue  # paired with -start; count once
        # operands: inside the outermost parens of the op call
        call = line.split(opcode + "(", 1)[1]
        depth, end = 1, 0
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        arglist = call[:end]
        # strip attribute-looking tails (channel_id=..) — operands come first
        operand_bytes = 0
        for tok in arglist.split(","):
            tok = tok.strip()
            if not tok or "=" in tok:
                break
            om = _OPERAND_RE.match(tok)
            if not om:
                continue
            t = types.get(om.group(1))
            if t is None:
                # operand may carry an inline type: f32[8,16] %name
                inline = _SHAPE_RE.search(tok)
                operand_bytes += _shape_bytes(tok) if inline else 0
            else:
                operand_bytes += _shape_bytes(t)

        gm = _GROUPS_BRACED_RE.search(line)
        if gm:
            group = len([x for x in gm.group(1).split(",") if x.strip() != ""])
        else:
            gm = _GROUPS_IOTA_RE.search(line)
            group = int(gm.group(2)) if gm else default_group
        stats.count += 1
        stats.operand_bytes += operand_bytes
        wire = operand_bytes * _ring_factor(kind, group)
        stats.wire_bytes += wire
        stats.by_kind[kind] += wire
    return stats


# --------------------------------------------------------------------------
# Full HLO walk: per-computation costs scaled by while-loop trip counts.
# XLA's cost_analysis counts loop bodies ONCE; lax.scan-built models
# (layer stacks, flash-attention blocks, SSD chunks) therefore under-report.
# --------------------------------------------------------------------------

_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DIMS_ATTR_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _split_computations(hlo_text: str) -> tuple[dict[str, list[str]], str | None]:
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # computation header: "%name (args) -> type {" possibly with nested
        # parens in tuple-typed args; name may contain dots
        if (
            line
            and not line[0].isspace()
            and stripped.endswith("{")
            and "->" in line
            and "(" in line
        ):
            head = line.split("(", 1)[0].strip()
            if head.startswith("ENTRY"):
                head = head[len("ENTRY"):].strip()
                cur = head.lstrip("%")
                entry = cur
            else:
                cur = head.lstrip("%")
            comps[cur] = []
            continue
        if cur is not None:
            if stripped == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps, entry


def _loop_trip_count(cond_lines: list[str]) -> int:
    """lax.scan conditions compare the induction var to a constant bound.
    The compare may be fusion-wrapped, so take the max integer constant in
    the (tiny) condition computation."""
    best = 1
    for line in cond_lines:
        m = _CONST_RE.search(line)
        if m:
            best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class HLOAnalysis:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: "CollectiveStats" = None  # type: ignore[assignment]


def _dot_flops_of_line(line: str, types: dict[str, str]) -> float:
    m = _DEF_RE.match(line)
    if not m:
        return 0.0
    out_elems = 0
    for sm in _SHAPE_RE.finditer(m.group("type")):
        n = 1
        for d in sm.group("dims").split(","):
            if d:
                n *= int(d)
        out_elems += n
    ops = _operand_names(line, m.group("opcode"))
    k = 1
    dm = _DIMS_ATTR_RE.search(line)
    if dm and ops:
        lhs_t = types.get(ops[0], "")
        sm = _SHAPE_RE.search(lhs_t)
        if sm:
            dims = [int(d) for d in sm.group("dims").split(",") if d]
            for idx in (int(x) for x in dm.group(1).split(",") if x):
                if idx < len(dims):
                    k *= dims[idx]
    return 2.0 * out_elems * k


def analyze_hlo(hlo_text: str, default_group: int) -> HLOAnalysis:
    """Trip-count-aware dot FLOPs, fusion-aware HBM bytes, collective stats."""
    types: dict[str, str] = {}
    defs: dict[str, tuple[str, list[str]]] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            types[m.group("name")] = m.group("type")
            op = m.group("opcode").split(".")[0]
            if op in ("convert", "reshape", "transpose", "copy", "bitcast",
                      "broadcast", "multiply"):
                defs[m.group("name")] = (op, _operand_names(line, m.group("opcode")))
    comps, entry = _split_computations(hlo_text)
    out = HLOAnalysis(collectives=CollectiveStats())
    if entry is None:
        return out
    seen_stack: list[str] = []

    def _raw_bytes(name: str) -> float:
        t = types.get(name)
        return _shape_bytes(t) if t else 0.0

    def tbytes(name: str, depth: int = 6) -> float:
        """Fusion-aware operand traffic: dequant chains
        multiply(convert(int8), broadcast(scale)) load the narrow sources."""
        if depth <= 0 or name not in defs:
            return _raw_bytes(name)
        op, ops = defs[name]
        if not ops:
            return _raw_bytes(name)
        if op in ("convert", "reshape", "transpose", "copy", "bitcast", "broadcast"):
            return tbytes(ops[0], depth - 1)
        if op == "multiply":
            return sum(tbytes(o, depth - 1) for o in ops[:2])
        return _raw_bytes(name)

    def walk(comp: str, mult: float, is_entry: bool):
        if comp not in comps or comp in seen_stack:
            return
        seen_stack.append(comp)
        for line in comps[comp]:
            m = _DEF_RE.match(line)
            if not m:
                continue
            opcode = m.group("opcode")
            base = opcode.split(".")[0]
            out_bytes = _shape_bytes(m.group("type"))

            if base == "while":
                wm = _WHILE_RE.search(line)
                if wm:
                    tm = _TRIP_RE.search(line)  # XLA annotates trip counts
                    if tm:
                        trips = int(tm.group(1))
                    else:
                        trips = _loop_trip_count(comps.get(wm.group(1), []))
                    walk(wm.group(2), mult * trips, False)
                continue
            if base in ("fusion", "call", "conditional", "map", "reduce", "sort",
                        "reduce-window", "scatter", "select-and-scatter", "reduce-scatter",
                        "all-reduce"):
                cm = _CALLS_RE.search(line)
                if cm:
                    for sub in cm.group(1).replace("%", "").split(","):
                        walk(sub.strip(), mult, False)

            if base == "parameter":
                if is_entry:
                    out.hbm_bytes += out_bytes
                continue
            if is_entry and line.lstrip().startswith("ROOT "):
                out.hbm_bytes += out_bytes

            if base == "dot":
                out.dot_flops += mult * _dot_flops_of_line(line, types)
                out.hbm_bytes += mult * (
                    out_bytes + sum(tbytes(n) for n in _operand_names(line, opcode))
                )
            elif base == "convolution":
                ops = _operand_names(line, opcode)
                out_dims = _dims_of(types.get(m.group("name"), m.group("type")))
                k_dims = _dims_of(types.get(ops[1], "")) if len(ops) > 1 else []
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                k_elems = 1
                for d in k_dims:
                    k_elems *= d
                # per-output-feature kernel elems: divide out the feature dim
                feat = max((d for d in k_dims if d in set(out_dims)), default=1)
                out.dot_flops += mult * 2.0 * out_elems * max(k_elems // max(feat, 1), 1)
                out.hbm_bytes += mult * (out_bytes + sum(tbytes(n) for n in ops))
            elif base == "sort":
                out.hbm_bytes += mult * (out_bytes + sum(tbytes(n) for n in _operand_names(line, opcode)))
            elif base == "gather":
                out.hbm_bytes += mult * 2 * out_bytes
            elif base == "scatter":
                ops = _operand_names(line, opcode)
                upd = tbytes(ops[2]) if len(ops) > 2 else 0.0
                out.hbm_bytes += mult * (2 * out_bytes + upd)
            elif base == "dynamic-slice":
                out.hbm_bytes += mult * out_bytes
            elif base == "dynamic-update-slice":
                ops = _operand_names(line, opcode)
                upd = tbytes(ops[1]) if len(ops) > 1 else 0.0
                out.hbm_bytes += mult * 2 * upd
            elif any(base == k or base.startswith(k) for k in COLLECTIVE_KINDS):
                if opcode.endswith("-done"):
                    continue
                operand_bytes = sum(tbytes(n) for n in _operand_names(line, opcode))
                kind = next(k for k in COLLECTIVE_KINDS if base == k or base.startswith(k))
                gm = _GROUPS_BRACED_RE.search(line)
                if gm:
                    group = len([x for x in gm.group(1).split(",") if x.strip()])
                else:
                    gm = _GROUPS_IOTA_RE.search(line)
                    group = int(gm.group(2)) if gm else default_group
                wire = operand_bytes * _ring_factor(kind, group) * mult
                out.collectives.count += int(mult)
                out.collectives.operand_bytes += operand_bytes * mult
                out.collectives.wire_bytes += wire
                out.collectives.by_kind[kind] += wire
                out.hbm_bytes += mult * (out_bytes + operand_bytes)
        seen_stack.pop()

    walk(entry, 1.0, True)
    return out


def _operand_names(line: str, opcode: str) -> list[str]:
    call = line.split(opcode + "(", 1)
    if len(call) < 2:
        return []
    seg = call[1]
    depth, end = 1, 0
    for i, ch in enumerate(seg):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    names = []
    for tok in seg[:end].split(","):
        tok = tok.strip()
        if not tok or "=" in tok:
            break
        # operand token forms: "%x" | "x" | "f32[128,256]{1,0} %x"
        om = re.search(r"%([\w.\-]+)\s*$", tok)
        if om is None and "[" not in tok and "(" not in tok:
            om = re.match(r"([\w.\-]+)$", tok)
        if om:
            names.append(om.group(1))
    return names


def fused_bytes_estimate(hlo_text: str) -> float:
    """Fusion-optimistic per-device HBM bytes for a TPU compilation.

    The CPU backend materializes every elementwise/convert/broadcast op, so
    raw ``bytes accessed`` overestimates TPU HBM traffic ~30× (see
    EXPERIMENTS.md §Dry-run methodology).  This estimator assumes perfect
    elementwise fusion and in-place updates:

      * ENTRY parameters read once; ENTRY root written once;
      * dot/convolution/sort/collectives: operands + outputs;
      * gather: 2× output (gathered rows in + out);
      * scatter: 2× output (read-modify-write) + updates;
      * dynamic-slice: output only; dynamic-update-slice: 2× update slice.
    """
    types: dict[str, str] = {}
    for m in _DEF_RE.finditer(hlo_text):
        types[m.group("name")] = m.group("type")

    def tbytes(name: str) -> float:
        t = types.get(name)
        return _shape_bytes(t) if t else 0.0

    total = 0.0
    in_entry = False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            in_entry = True
            continue
        if in_entry and line.startswith("}"):
            in_entry = False
        m = _DEF_RE.match(line)
        if not m:
            continue
        opcode = m.group("opcode")
        out_bytes = _shape_bytes(m.group("type"))
        base = opcode.split(".")[0]
        if base == "parameter":
            if in_entry:
                total += out_bytes
            continue
        if in_entry and line.lstrip().startswith("ROOT "):
            total += out_bytes  # entry outputs written once
        if base in ("dot", "convolution", "sort") or base.startswith(
            ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
        ):
            total += out_bytes
            for name in _operand_names(line, opcode):
                total += tbytes(name)
        elif base == "gather":
            total += 2 * out_bytes
        elif base == "scatter":
            ops = _operand_names(line, opcode)
            upd = tbytes(ops[2]) if len(ops) > 2 else 0.0
            total += 2 * out_bytes + upd
        elif base == "dynamic-slice":
            total += out_bytes
        elif base == "dynamic-update-slice":
            ops = _operand_names(line, opcode)
            upd = tbytes(ops[1]) if len(ops) > 1 else 0.0
            total += 2 * upd
    return total


@dataclasses.dataclass
class RooflineReport:
    cell: str
    chips: int
    flops_global: float
    hbm_bytes_global: float
    collective_wire_bytes_per_dev: float
    collective_operand_bytes_per_dev: float
    collective_count: int
    by_kind: dict
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    bytes_per_device: float | None = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s, "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: catches remat/redundancy waste."""
        return self.model_flops / self.flops_global if self.flops_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chips' peak compute the step achieves at the bound
        (MFU at the modeled bottleneck)."""
        ideal = self.model_flops / (self.chips * TPU_V5E.peak_bf16)
        return ideal / self.bound_time_s if self.bound_time_s else 0.0

    def row(self) -> dict:
        return {
            "cell": self.cell,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def build_report(
    cell: str,
    chips: int,
    flops_per_device: float,
    hbm_bytes_per_device: float,
    hlo_text: str,
    model_flops: float,
    tpu: TPUConfig = TPU_V5E,
    bytes_per_device: float | None = None,
    use_fused_bytes: bool = True,
) -> RooflineReport:
    """cost_analysis() quantities are per-device (the compiled module is the
    per-device SPMD program); globals are ×chips.

    Both FLOPs and bytes default to the trip-count-aware HLO walk
    (analyze_hlo): XLA's cost_analysis counts while-loop bodies once, so
    scan-built blocks (flash-attention, SSD chunks) under-report; and the CPU
    backend's raw 'bytes accessed' is ~30× a TPU target's because elementwise
    ops don't fuse.  The raw cost_analysis values are kept in the dry-run
    record for reference."""
    if use_fused_bytes:
        a = analyze_hlo(hlo_text, default_group=chips)
        col = a.collectives
        hbm_bytes_per_device = a.hbm_bytes
        # dots dominate; add the non-dot remainder from cost_analysis as-is
        flops_per_device = max(flops_per_device, a.dot_flops)
    else:
        col = parse_collectives(hlo_text, default_group=chips)
    flops_global = flops_per_device * chips
    hbm_global = hbm_bytes_per_device * chips
    return RooflineReport(
        cell=cell,
        chips=chips,
        flops_global=flops_global,
        hbm_bytes_global=hbm_global,
        collective_wire_bytes_per_dev=col.wire_bytes,
        collective_operand_bytes_per_dev=col.operand_bytes,
        collective_count=col.count,
        by_kind=dict(col.by_kind),
        compute_s=flops_global / (chips * tpu.peak_bf16),
        memory_s=hbm_global / (chips * tpu.hbm_bw),
        collective_s=col.wire_bytes / tpu.ici_bw,
        model_flops=model_flops,
        bytes_per_device=bytes_per_device,
    )
