"""User-facing bit-exact PIM simulation wrappers.

These run the AritPIM plane algorithms in execute mode on packed planes and
convert back to ordinary arrays.  Each call also reports the analytical cost
— which now comes from the ``cost`` executor backend over the compiled
Schedule IR (``repro.core.ir``), the same artifact the interpreter and
Pallas backends execute, rather than from ad-hoc per-call gate counters.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import aritpim, bitplanes, ir
from .machine import PlaneVM


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Analytical cost of one vectored PIM op (independent of vector length).

    ``gates`` is the recorded NOR count — the paper's latency unit.
    ``optimized_gates``/``peak_cols`` report what the compiled schedule
    actually executes after the IR pass pipeline.  The ``dram_*`` properties
    report the independently derived DRAM-basis compilation of the same
    netlist (MAJ3/NOT gates, AAP/TRA row-command cycles, peak rows including
    the reserved compute-row group) — not clock-scaled memristive numbers.
    They compile lazily on first access (then hit ``ir``'s compile cache),
    so the bit-exact simulation path never pays a second compile.
    """

    name: str
    gates: int  # recorded serial NOR gates (= the paper's latency unit)
    io_bits: int  # input+output bits per element (CC denominator)
    optimized_gates: int = 0  # post-pipeline NOR count (≤ gates)
    peak_cols: int = 0  # peak live crossbar columns after allocation
    op_key: str = ""  # _OP_TABLE key for the per-basis lookups
    nbits: int = 32

    @property
    def compute_complexity(self) -> float:
        """Paper §3: gates per I/O bit."""
        return self.gates / self.io_bits

    @property
    def dram(self) -> "ir.CostReport":
        """The dram-basis CostReport (compiled on first access, then cached)."""
        return ir.op_cost(self.op_key, self.nbits, basis="dram")

    @property
    def dram_gates(self) -> int:  # MAJ3+NOT count
        return self.dram.gates

    @property
    def dram_maj_gates(self) -> int:  # MAJ3 rows alone (the TRA count)
        return self.dram.maj_gates

    @property
    def dram_cycles(self) -> int:  # AAP/TRA row-command cycles
        return self.dram.cycles

    @property
    def dram_peak_rows(self) -> int:  # allocation peak + reserved compute rows
        return self.dram.peak_rows


def _op_cost(name: str, op_key: str, nbits: int) -> OpCost:
    io_bits = aritpim.op_io_bits(op_key, nbits)  # from _OP_TABLE metadata
    rep = ir.op_cost(op_key, nbits)
    return OpCost(name, rep.recorded_gates, io_bits,
                  optimized_gates=rep.gates, peak_cols=rep.num_cols,
                  op_key=op_key, nbits=nbits)


def _run(fn, nbits_in, nbits_out, arrays, to_planes, from_planes):
    n = arrays[0].shape[0]
    vm = PlaneVM(mode="execute", n_words=bitplanes.num_words(n))
    planes = [to_planes(a) for a in arrays]
    out = fn(vm, *planes)
    assert len(out) == nbits_out
    return from_planes(out, n)


# -------------------------------------------------------------- fixed point

def fixed_add(x, y, nbits: int = 32):
    x, y = jnp.asarray(x), jnp.asarray(y)
    res = _run(
        aritpim.fixed_add, nbits, nbits, (x, y),
        functools.partial(bitplanes.int_to_planes, nbits=nbits),
        lambda p, n: bitplanes.planes_to_int(p, n, signed=True),
    )
    return res, _op_cost(f"fixed{nbits}_add", "fixed_add", nbits)


def fixed_mul(x, y, nbits: int = 32):
    x, y = jnp.asarray(x), jnp.asarray(y)
    res = _run(
        aritpim.fixed_mul_signed, nbits, 2 * nbits, (x, y),
        functools.partial(bitplanes.int_to_planes, nbits=nbits),
        lambda p, n: bitplanes.planes_to_int(p[:32], n, signed=True) if nbits * 2 >= 32
        else bitplanes.planes_to_int(p, n, signed=True),
    )
    return res, _op_cost(f"fixed{nbits}_mul", "fixed_mul", nbits)


def fixed_mul_full(x, y, nbits: int = 32):
    """Full 2N-bit product as (lo_uint32, hi_uint32) for nbits=32."""
    x, y = jnp.asarray(x), jnp.asarray(y)
    n = x.shape[0]
    vm = PlaneVM(mode="execute", n_words=bitplanes.num_words(n))
    A = bitplanes.int_to_planes(x, nbits)
    B = bitplanes.int_to_planes(y, nbits)
    P = aritpim.fixed_mul_signed(vm, A, B)
    lo = bitplanes.planes_to_int(P[:nbits], n, signed=False)
    hi = bitplanes.planes_to_int(P[nbits:], n, signed=False)
    return (lo, hi), _op_cost(f"fixed{nbits}_mul", "fixed_mul", nbits)


# ------------------------------------------------------------ floating point

def float_add(x, y):
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    res = _run(
        aritpim.float_add, 32, 32, (x, y),
        bitplanes.f32_to_planes, bitplanes.planes_to_f32,
    )
    return res, _op_cost("float32_add", "float_add", 32)


def float_sub(x, y):
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    res = _run(
        aritpim.float_sub, 32, 32, (x, y),
        bitplanes.f32_to_planes, bitplanes.planes_to_f32,
    )
    return res, _op_cost("float32_sub", "float_sub", 32)


def float_mul(x, y):
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    res = _run(
        aritpim.float_mul, 32, 32, (x, y),
        bitplanes.f32_to_planes, bitplanes.planes_to_f32,
    )
    return res, _op_cost("float32_mul", "float_mul", 32)


def bf16_add(x, y):
    x = jnp.asarray(x, jnp.bfloat16)
    y = jnp.asarray(y, jnp.bfloat16)
    res = _run(
        aritpim.bf16_add, 16, 16, (x, y),
        bitplanes.bf16_to_planes, bitplanes.planes_to_bf16,
    )
    return res, _op_cost("bf16_add", "bf16_add", 16)


def bf16_mul(x, y):
    x = jnp.asarray(x, jnp.bfloat16)
    y = jnp.asarray(y, jnp.bfloat16)
    res = _run(
        aritpim.bf16_mul, 16, 16, (x, y),
        bitplanes.bf16_to_planes, bitplanes.planes_to_bf16,
    )
    return res, _op_cost("bf16_mul", "bf16_mul", 16)


def fixed_div(x, y, nbits: int = 32):
    """Signed division (C truncation semantics); x//0 → implementation-defined."""
    x, y = jnp.asarray(x), jnp.asarray(y)
    res = _run(
        lambda vm, A, B: aritpim.fixed_div_signed(vm, A, B)[0], nbits, nbits, (x, y),
        functools.partial(bitplanes.int_to_planes, nbits=nbits),
        lambda p, n: bitplanes.planes_to_int(p, n, signed=True),
    )
    return res, _op_cost(f"fixed{nbits}_div", "fixed_div", nbits)


def float_div(x, y):
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    res = _run(
        aritpim.float_div, 32, 32, (x, y),
        bitplanes.f32_to_planes, bitplanes.planes_to_f32,
    )
    return res, _op_cost("float32_div", "float_div", 32)


# ------------------------------------------------- fused multi-op programs


@functools.lru_cache(maxsize=None)
def _mac_program(dtype):
    """The fused ``a * b + c`` program at a PimType (traced once per type;
    compilations are cached downstream by structure)."""
    import repro.pim as pim

    return pim.trace(lambda a, b, c: a * b + c, dtype)


def mac_cost(dtype=None, basis: str = "memristive",
             passes: tuple[str, ...] | None = None) -> "ir.CostReport":
    """Program-level CostReport of the fused MAC (``a*b + c``) — the
    flagship composed program: one compiled schedule, intermediates never
    leave the array (compare ``hbm_planes`` with separate mul+add
    dispatches).  ``dtype`` is a ``bitplanes.PimType`` (default float32)."""
    from . import bitplanes

    return _mac_program(dtype or bitplanes.F32).cost(
        basis=basis, passes=ir.DEFAULT_PASSES if passes is None else passes)


def float_mac(x, y, c):
    """Fused float32 ``x*y + c``: execute-mode bit-exact oracle (per-op IEEE
    rounding, like the compiled program) + the fused program's CostReport."""
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    n = x.shape[0]
    vm = PlaneVM(mode="execute", n_words=bitplanes.num_words(n))
    P = aritpim.float_mul(vm, bitplanes.f32_to_planes(x), bitplanes.f32_to_planes(y))
    S = aritpim.float_add(vm, P, bitplanes.f32_to_planes(c))
    return bitplanes.planes_to_f32(S, n), mac_cost()


# Jitted variants (value path only; costs are static per op).
fixed_add_jit = jax.jit(lambda x, y: fixed_add(x, y)[0])
float_add_jit = jax.jit(lambda x, y: float_add(x, y)[0])
float_mul_jit = jax.jit(lambda x, y: float_mul(x, y)[0])
