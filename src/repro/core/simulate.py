"""User-facing bit-exact PIM simulation wrappers.

These run the AritPIM plane algorithms in execute mode on packed planes and
convert back to ordinary arrays.  Each call also reports the analytical cost
(gate count → cycles → throughput under a PIM config; see ``costmodel``).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import aritpim, bitplanes
from .machine import PlaneVM


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Analytical cost of one vectored PIM op (independent of vector length)."""

    name: str
    gates: int  # serial NOR gates (= the paper's latency unit before init)
    io_bits: int  # input+output bits per element (CC denominator)

    @property
    def compute_complexity(self) -> float:
        """Paper §3: gates per I/O bit."""
        return self.gates / self.io_bits


def _run(fn, nbits_in, nbits_out, arrays, to_planes, from_planes):
    n = arrays[0].shape[0]
    vm = PlaneVM(mode="execute", n_words=bitplanes.num_words(n))
    planes = [to_planes(a) for a in arrays]
    out = fn(vm, *planes)
    assert len(out) == nbits_out
    return from_planes(out, n), vm.gates


# -------------------------------------------------------------- fixed point

def fixed_add(x, y, nbits: int = 32):
    x, y = jnp.asarray(x), jnp.asarray(y)
    res, gates = _run(
        aritpim.fixed_add, nbits, nbits, (x, y),
        functools.partial(bitplanes.int_to_planes, nbits=nbits),
        lambda p, n: bitplanes.planes_to_int(p, n, signed=True),
    )
    return res, OpCost(f"fixed{nbits}_add", gates, 3 * nbits)


def fixed_mul(x, y, nbits: int = 32):
    x, y = jnp.asarray(x), jnp.asarray(y)
    res, gates = _run(
        aritpim.fixed_mul_signed, nbits, 2 * nbits, (x, y),
        functools.partial(bitplanes.int_to_planes, nbits=nbits),
        lambda p, n: bitplanes.planes_to_int(p[:32], n, signed=True) if nbits * 2 >= 32
        else bitplanes.planes_to_int(p, n, signed=True),
    )
    return res, OpCost(f"fixed{nbits}_mul", gates, 4 * nbits)


def fixed_mul_full(x, y, nbits: int = 32):
    """Full 2N-bit product as (lo_uint32, hi_uint32) for nbits=32."""
    x, y = jnp.asarray(x), jnp.asarray(y)
    n = x.shape[0]
    vm = PlaneVM(mode="execute", n_words=bitplanes.num_words(n))
    A = bitplanes.int_to_planes(x, nbits)
    B = bitplanes.int_to_planes(y, nbits)
    P = aritpim.fixed_mul_signed(vm, A, B)
    lo = bitplanes.planes_to_int(P[:nbits], n, signed=False)
    hi = bitplanes.planes_to_int(P[nbits:], n, signed=False)
    return (lo, hi), OpCost(f"fixed{nbits}_mul", vm.gates, 4 * nbits)


# ------------------------------------------------------------ floating point

def float_add(x, y):
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    res, gates = _run(
        aritpim.float_add, 32, 32, (x, y),
        bitplanes.f32_to_planes, bitplanes.planes_to_f32,
    )
    return res, OpCost("float32_add", gates, 3 * 32)


def float_sub(x, y):
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    res, gates = _run(
        aritpim.float_sub, 32, 32, (x, y),
        bitplanes.f32_to_planes, bitplanes.planes_to_f32,
    )
    return res, OpCost("float32_sub", gates, 3 * 32)


def float_mul(x, y):
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    res, gates = _run(
        aritpim.float_mul, 32, 32, (x, y),
        bitplanes.f32_to_planes, bitplanes.planes_to_f32,
    )
    return res, OpCost("float32_mul", gates, 3 * 32)


def fixed_div(x, y, nbits: int = 32):
    """Signed division (C truncation semantics); x//0 → implementation-defined."""
    x, y = jnp.asarray(x), jnp.asarray(y)
    res, gates = _run(
        lambda vm, A, B: aritpim.fixed_div_signed(vm, A, B)[0], nbits, nbits, (x, y),
        functools.partial(bitplanes.int_to_planes, nbits=nbits),
        lambda p, n: bitplanes.planes_to_int(p, n, signed=True),
    )
    return res, OpCost(f"fixed{nbits}_div", gates, 3 * nbits)


def float_div(x, y):
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    res, gates = _run(
        aritpim.float_div, 32, 32, (x, y),
        bitplanes.f32_to_planes, bitplanes.planes_to_f32,
    )
    return res, OpCost("float32_div", gates, 3 * 32)


# Jitted variants (value path only; costs are static per op).
fixed_add_jit = jax.jit(lambda x, y: fixed_add(x, y)[0])
float_add_jit = jax.jit(lambda x, y: float_add(x, y)[0])
float_mul_jit = jax.jit(lambda x, y: float_mul(x, y)[0])
