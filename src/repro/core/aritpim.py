"""AritPIM-style bit-serial element-parallel arithmetic (paper §3, refs [3,4]).

Every algorithm is written once against the :class:`~repro.core.machine.PlaneVM`
gate DSL and therefore yields simultaneously

* a bit-exact simulation (execute mode, packed-``uint32`` planes),
* an exact NOR-gate count (the paper's compute-complexity unit), and
* a recordable flat NOR schedule for the Pallas kernel.

Conventions: all plane lists are LSB-first.  float32 layout (LSB-first):
planes[0:23] mantissa, planes[23:31] exponent, planes[31] sign.

Fixed-point addition is the paper's reference point: a 9-NOR full adder
rippled N times → 9N gates (paper §3).  Multiplication is schoolbook
shift-and-add ≈ 10N² gates (paper §3: "approximately 10N²").  Floating point
follows IEEE 754 binary32 with round-to-nearest-even, gradual underflow
(subnormals), signed zeros and Inf/NaN propagation — the properties FloatPIM
got wrong and AritPIM fixed (paper §1, §3).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from .machine import PlaneVM

Plane = Any  # jnp array (execute) or int col id (record)


# --------------------------------------------------------------------------
# Ripple-carry building blocks
# --------------------------------------------------------------------------

def ripple_add(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane], cin: Plane | None = None):
    """N-bit ripple-carry add → (sum planes, carry-out).  9 gates/bit."""
    assert len(A) == len(B)
    c = cin if cin is not None else vm.const0()
    out = []
    for a, b in zip(A, B):
        s, c = vm.full_adder(a, b, c)
        out.append(s)
    return out, c


def ripple_sub(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    """A - B (two's complement).  Returns (diff, no_borrow); no_borrow=1 ⟺ A ≥ B
    for unsigned interpretation."""
    nB = [vm.not_(b) for b in B]
    return ripple_add(vm, A, nB, cin=vm.const1())


def ripple_inc(vm: PlaneVM, A: Sequence[Plane], cin: Plane):
    """A + cin (single-bit increment chain): 8 gates/bit."""
    out = []
    c = cin
    for a in A:
        s = vm.xor(a, c)
        c = vm.and_(a, c)
        out.append(s)
    return out, c


def ripple_dec(vm: PlaneVM, A: Sequence[Plane], bin_: Plane):
    """A - bin_ (single-bit borrow chain)."""
    out = []
    b = bin_
    for a in A:
        s = vm.xor(a, b)
        b = vm.and_(vm.not_(a), b)
        out.append(s)
    return out, b


def const_planes(vm: PlaneVM, value: int, nbits: int) -> list[Plane]:
    return [vm.const1() if (value >> j) & 1 else vm.const0() for j in range(nbits)]


def mux_planes(vm: PlaneVM, s: Plane, X: Sequence[Plane], Y: Sequence[Plane]) -> list[Plane]:
    """Elementwise s ? X : Y."""
    assert len(X) == len(Y)
    return [vm.mux(s, x, y) for x, y in zip(X, Y)]


def zero_planes(vm: PlaneVM, n: int) -> list[Plane]:
    z = vm.const0()
    return [z] * n


def and_tree(vm: PlaneVM, xs: Sequence[Plane]) -> Plane:
    return vm.not_(vm.or_tree([vm.not_(x) for x in xs]))


def unsigned_lt(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]) -> Plane:
    """1 ⟺ A < B (unsigned)."""
    _, no_borrow = ripple_sub(vm, list(A), list(B))
    return vm.not_(no_borrow)


def extend(vm: PlaneVM, A: Sequence[Plane], n: int) -> list[Plane]:
    A = list(A)
    while len(A) < n:
        A.append(vm.const0())
    return A


# --------------------------------------------------------------------------
# Variable shifters (log-shifter with MUX stages) and leading-zero count
# --------------------------------------------------------------------------

def shift_right_var(vm: PlaneVM, R: Sequence[Plane], d: Sequence[Plane], sticky: Plane):
    """Logical right shift of register R (LSB-first) by value d, OR-ing
    shifted-out bits into ``sticky``.  Returns (R', sticky')."""
    R = list(R)
    n = len(R)
    for k, dk in enumerate(d):
        amt = 1 << k
        lost = vm.or_tree(R[: min(amt, n)])
        sticky = vm.or_(sticky, vm.and_(dk, lost))
        shifted = [R[i + amt] if i + amt < n else vm.const0() for i in range(n)]
        R = mux_planes(vm, dk, shifted, R)
    return R, sticky


def shift_left_var(vm: PlaneVM, R: Sequence[Plane], d: Sequence[Plane]):
    """Logical left shift (zero fill).  Overflowing bits are dropped (caller
    guarantees they are zero)."""
    R = list(R)
    n = len(R)
    for k, dk in enumerate(d):
        amt = 1 << k
        shifted = [R[i - amt] if i - amt >= 0 else vm.const0() for i in range(n)]
        R = mux_planes(vm, dk, shifted, R)
    return R


def leading_zero_count(vm: PlaneVM, R: Sequence[Plane]):
    """LZC of register R (LSB-first, MSB = R[-1]).  Returns (lzc planes, all_zero).
    For all-zero input lzc reads n-1 from the encoder; use the flag."""
    R = list(R)
    n = len(R)
    pref = [None] * n  # pref[i] = OR(R[n-1] .. R[i])
    pref[n - 1] = R[n - 1]
    for i in range(n - 2, -1, -1):
        pref[i] = vm.or_(pref[i + 1], R[i])
    all_zero = vm.not_(pref[0])
    h = [None] * n  # one-hot leading-one position
    h[n - 1] = R[n - 1]
    for i in range(n - 1):
        h[i] = vm.and_(R[i], vm.not_(pref[i + 1]))
    nbits = max(1, (n - 1).bit_length())
    lzc = []
    for k in range(nbits):
        terms = [h[i] for i in range(n) if ((n - 1 - i) >> k) & 1]
        lzc.append(vm.or_tree(terms) if terms else vm.const0())
    return lzc, all_zero


# --------------------------------------------------------------------------
# Fixed point (paper §3)
# --------------------------------------------------------------------------

def fixed_add(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    """N-bit two's complement add (wrapping), 9N gates — the paper's headline."""
    s, _ = ripple_add(vm, A, B)
    return s


def fixed_sub(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    s, _ = ripple_sub(vm, A, B)
    return s


def negate(vm: PlaneVM, A: Sequence[Plane]):
    nA = [vm.not_(a) for a in A]
    s, _ = ripple_inc(vm, nA, vm.const1())
    return s


def fixed_mul_unsigned(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    """Unsigned schoolbook multiply: N×M → N+M bits, ≈10·N·M gates (paper §3)."""
    n, m = len(A), len(B)
    nA = [vm.not_(a) for a in A]
    nB = [vm.not_(b) for b in B]
    acc = zero_planes(vm, n + m)
    carry_into_top = None
    for j in range(m):
        pp = [vm.nor(nA[i], nB[j]) for i in range(n)]  # a_i AND b_j
        seg, cout = ripple_add(vm, acc[j : j + n], pp)
        acc[j : j + n] = seg
        if j + n < n + m:
            # carry ripples into a zero column: plain copy
            acc[j + n] = cout
    del carry_into_top
    return acc


def fixed_mul_signed(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    """Signed N×N → 2N via sign-magnitude around the unsigned core.
    |INT_MIN| is representable unsigned, so conditional negation is exact."""
    n = len(A)
    sa, sb = A[-1], B[-1]
    absA = mux_planes(vm, sa, negate(vm, A), list(A))
    absB = mux_planes(vm, sb, negate(vm, B), list(B))
    P = fixed_mul_unsigned(vm, absA, absB)
    sp = vm.xor(sa, sb)
    return mux_planes(vm, sp, negate(vm, P), P)


def fixed_div_unsigned(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    """Unsigned restoring division: N-bit quotient + remainder, ≈16N² gates.
    Division by zero yields Q = all-ones, R = A (documented convention)."""
    n = len(A)
    R = zero_planes(vm, n + 1)  # one headroom bit for the shifted compare
    Bx = extend(vm, list(B), n + 1)
    Q: list[Plane] = [None] * n  # type: ignore[list-item]
    for i in range(n - 1, -1, -1):
        R = [A[i]] + R[:-1]  # R = (R << 1) | a_i
        diff, no_borrow = ripple_sub(vm, R, Bx)
        Q[i] = no_borrow  # 1 ⟺ R >= B
        R = mux_planes(vm, no_borrow, diff, R)
    return Q, R[:n]


def fixed_div_signed(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    """Signed division (C semantics: truncation toward zero)."""
    sa, sb = A[-1], B[-1]
    absA = mux_planes(vm, sa, negate(vm, A), list(A))
    absB = mux_planes(vm, sb, negate(vm, B), list(B))
    Q, R = fixed_div_unsigned(vm, absA, absB)
    sq = vm.xor(sa, sb)
    Q = mux_planes(vm, sq, negate(vm, Q), Q)
    R = mux_planes(vm, sa, negate(vm, R), R)  # remainder takes dividend sign
    return Q, R


def float_div(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    """IEEE-754 binary32 division, RNE, subnormals, Inf/NaN/zero cases.

    Mantissa path: pre-normalize subnormal inputs (LZC), 26-bit long division
    of the significands with a sticky remainder, 1-step normalize, gradual
    underflow, round-to-nearest-even."""
    a = _unpack_f32(vm, A)
    b = _unpack_f32(vm, B)
    s = vm.xor(a["s"], b["s"])

    # --- pre-normalize significands (subnormal inputs have leading zeros)
    def prenorm(M, e_eff):
        lz, _ = leading_zero_count(vm, M)  # 5-bit (n=24)
        Mn = shift_left_var(vm, M, lz)
        e11 = extend(vm, list(e_eff), 11)
        e_adj, _ = ripple_sub(vm, e11, extend(vm, lz, 11))
        return Mn, e_adj

    Ma, ea = prenorm(a["M"], a["e_eff"])
    Mb, eb = prenorm(b["M"], b["e_eff"])

    # --- exponent: e = ea - eb + 127  (11-bit two's complement)
    E, _ = ripple_sub(vm, ea, eb)
    E, _ = ripple_add(vm, E, const_planes(vm, 127, 11))

    # --- quotient of normalized significands: restoring long division of
    # X = Ma·2^26 by Mb (50 feed steps: 24 integer bits MSB-first, then 26
    # fractional zeros).  Quotient = floor(Ma·2^26/Mb) ∈ (2^25, 2^27).
    R = zero_planes(vm, 25)
    Bx = extend(vm, Mb, 25)
    feed_bits = list(reversed(list(Ma)))  # MSB first
    q_msb_first: list[Plane] = []
    for step in range(24 + 26):
        feed = feed_bits[step] if step < 24 else vm.const0()
        R = [feed] + R[:-1]
        diff, no_borrow = ripple_sub(vm, R, Bx)
        q_msb_first.append(no_borrow)
        R = mux_planes(vm, no_borrow, diff, R)
    sticky = vm.or_tree(R)  # non-zero remainder
    Q = list(reversed(q_msb_first))[:27]  # LSB-first, 27 significant bits

    # Q in (2^25, 2^27): leading one at 26 (quotient ≥ 1) or 25 (< 1)
    lead1 = Q[26]
    # if quotient < 1: shift LEFT 1 (LSB-first: prepend zero), e -= 1
    Qn = mux_planes(vm, lead1, Q, [vm.const0()] + Q[:-1])
    E, _ = ripple_dec(vm, E, vm.not_(lead1))

    # --- gradual underflow: if E <= 0 shift right by (1 - E) with sticky
    one11 = const_planes(vm, 1, 11)
    t, _ = ripple_sub(vm, one11, E)
    e_le0 = vm.not_(t[10])
    E_is1 = vm.not_(vm.or_tree([vm.xor(x, y) for x, y in zip(E, one11)]))
    need_den = vm.and_(e_le0, vm.not_(E_is1))
    t_clamped = mux_planes(vm, need_den, t, zero_planes(vm, 11))
    big_t = vm.or_tree(t_clamped[6:])
    lost = vm.or_tree(Qn)
    Qn, sticky = shift_right_var(vm, Qn, t_clamped[:6], sticky)
    sticky = vm.or_(sticky, vm.and_(big_t, lost))
    Qn = mux_planes(vm, big_t, zero_planes(vm, 27), Qn)
    E = mux_planes(vm, need_den, one11, E)

    # --- round to nearest even: significand = bits [3..26] (hidden at 26),
    # G = bit 2, R = bit 1, S = bit 0 ∨ remainder-sticky
    g, r = Qn[2], Qn[1]
    st = vm.or_(sticky, Qn[0])
    lsb = Qn[3]
    inc = vm.and_(g, vm.or_tree([r, st, lsb]))
    Mr, cr = ripple_inc(vm, Qn[3:27], inc)  # 24 bits incl hidden
    E, _ = ripple_inc(vm, E, cr)
    hidden_out = vm.or_(Mr[23], cr)
    m_out = mux_planes(vm, cr, zero_planes(vm, 23), Mr[0:23])
    e_enc = [vm.and_(hidden_out, x) for x in E[:8]]

    ge255 = vm.or_(vm.or_(E[8], vm.or_(E[9], E[10])), and_tree(vm, E[:8]))
    # E sign only possible pre-denorm; after mux E >= 1
    normal = _pack_f32(vm, s, e_enc, m_out)

    # --- specials
    res_nan = vm.or_tree([
        a["nan"], b["nan"],
        vm.and_(a["zero"], b["zero"]),  # 0/0
        vm.and_(a["inf"], b["inf"]),    # inf/inf
    ])
    res_inf = vm.and_(vm.or_(a["inf"], b["zero"]), vm.not_(res_nan))
    res_zero = vm.and_(vm.or_(a["zero"], b["inf"]), vm.not_(res_nan))

    zero_planes32 = [vm.const0()] * 23 + [vm.const0()] * 8 + [s]
    out = mux_planes(vm, ge255, _inf_planes(vm, s), normal)
    out = mux_planes(vm, res_zero, zero_planes32, out)
    out = mux_planes(vm, res_inf, _inf_planes(vm, s), out)
    out = mux_planes(vm, res_nan, _qnan_planes(vm), out)
    return out


# --------------------------------------------------------------------------
# IEEE-754 binary floating point, format-parameterized (paper §3, AritPIM [3])
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """IEEE-754-style binary format: LSB-first layout [mantissa | exp | sign]."""

    e_bits: int
    m_bits: int

    @property
    def width(self) -> int:
        return 1 + self.e_bits + self.m_bits

    @property
    def bias(self) -> int:
        return (1 << (self.e_bits - 1)) - 1


FLOAT32 = FloatFormat(e_bits=8, m_bits=23)
BFLOAT16 = FloatFormat(e_bits=8, m_bits=7)


def _unpack_float(vm: PlaneVM, X: Sequence[Plane], fmt: FloatFormat):
    mb, eb = fmt.m_bits, fmt.e_bits
    m = list(X[0:mb])
    e = list(X[mb:mb + eb])
    s = X[mb + eb]
    hidden = vm.or_tree(e)  # e != 0
    exp_all1 = and_tree(vm, e)
    m_nonzero = vm.or_tree(m)
    is_nan = vm.and_(exp_all1, m_nonzero)
    is_inf = vm.and_(exp_all1, vm.not_(m_nonzero))
    is_zero = vm.and_(vm.not_(hidden), vm.not_(m_nonzero))
    # effective exponent: subnormals live at scale e=1
    e_eff = [vm.or_(e[0], vm.not_(hidden))] + e[1:]
    M = m + [hidden]  # (m_bits+1)-bit significand with hidden bit
    return dict(s=s, e=e, m=m, e_eff=e_eff, M=M, hidden=hidden,
                nan=is_nan, inf=is_inf, zero=is_zero)


def _qnan_planes(vm: PlaneVM, fmt: FloatFormat = FLOAT32):
    one, zero = vm.const1(), vm.const0()
    m = [zero] * (fmt.m_bits - 1) + [one]  # quiet bit
    e = [one] * fmt.e_bits
    return m + e + [zero]


def _inf_planes(vm: PlaneVM, sign: Plane, fmt: FloatFormat = FLOAT32):
    one, zero = vm.const1(), vm.const0()
    return [zero] * fmt.m_bits + [one] * fmt.e_bits + [sign]


def _pack_float(vm: PlaneVM, s: Plane, e: Sequence[Plane], m: Sequence[Plane],
                fmt: FloatFormat):
    assert len(e) == fmt.e_bits and len(m) == fmt.m_bits
    return list(m) + list(e) + [s]


def _unpack_f32(vm: PlaneVM, X: Sequence[Plane]):
    return _unpack_float(vm, X, FLOAT32)


def _pack_f32(vm: PlaneVM, s: Plane, e: Sequence[Plane], m: Sequence[Plane]):
    return _pack_float(vm, s, e, m, FLOAT32)


def float_add_fmt(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane],
                  fmt: FloatFormat = FLOAT32):
    """IEEE-754 addition for any (e_bits, m_bits) format: RNE, subnormals,
    ±0, Inf/NaN.  float32 and bfloat16 are instantiations of this netlist."""
    mb, eb = fmt.m_bits, fmt.e_bits
    reg = mb + 4  # [s, r, g | M] with the hidden bit on top
    a = _unpack_float(vm, A, fmt)
    b = _unpack_float(vm, B, fmt)
    eff_sub = vm.xor(a["s"], b["s"])

    # --- magnitude compare on (e, m) as a (width-1)-bit integer, swap to L >= S
    magA = list(A[0:mb + eb])
    magB = list(B[0:mb + eb])
    lt = unsigned_lt(vm, magA, magB)  # |A| < |B|
    e_l = mux_planes(vm, lt, b["e_eff"], a["e_eff"])
    e_s = mux_planes(vm, lt, a["e_eff"], b["e_eff"])
    M_l = mux_planes(vm, lt, b["M"], a["M"])
    M_s = mux_planes(vm, lt, a["M"], b["M"])
    s_l = vm.mux(lt, b["s"], a["s"])

    # --- align smaller significand: registers are reg bits = [s, r, g | M<<3]
    d, _ = ripple_sub(vm, e_l, e_s)  # e_l >= e_s by the swap
    Sreg = zero_planes(vm, 3) + M_s
    sticky = vm.const0()
    # low shift stages cover 0..2^klow-1 >= reg-1; higher d bits empty the reg
    klow = max(1, (reg - 1).bit_length())
    Sreg, sticky = shift_right_var(vm, Sreg, d[:klow], sticky)
    if klow < eb:
        top_big = vm.or_tree(d[klow:])  # d >= 2^klow: all out
        lost_all = vm.or_tree(Sreg)
        sticky = vm.or_(sticky, vm.and_(top_big, lost_all))
        Sreg = mux_planes(vm, top_big, zero_planes(vm, reg), Sreg)

    # --- add/sub
    Lreg = zero_planes(vm, 3) + M_l
    Bx = [vm.xor(x, eff_sub) for x in Sreg]
    R, cout = ripple_add(vm, Lreg, Bx, cin=eff_sub)
    top = vm.and_(vm.not_(eff_sub), cout)  # bit reg (add overflow)
    V = R + [top]  # reg+1 bits
    # Effective subtraction with shifted-out bits: the true result lies in
    # (V-1, V) at bottom-bit scale — the sticky acts as a *borrow* here
    # (classic FP-adder correction; without it results are 1 ULP high).
    borrow = vm.and_(eff_sub, sticky)
    V, _ = ripple_dec(vm, V, borrow)

    # --- normalize: conditional right-1 (top set), then clamped left shift
    cond = top
    W = [vm.mux(cond, V[i + 1], V[i]) for i in range(reg)]
    sticky = vm.or_(sticky, vm.and_(cond, V[0]))
    e_base, _ = ripple_inc(vm, e_l + [vm.const0()], cond)  # eb+1 bits
    lz, all_zero = leading_zero_count(vm, W)
    lzx = extend(vm, lz, eb + 1)
    e_m1, _ = ripple_sub(vm, e_base, const_planes(vm, 1, eb + 1))
    lz_small = unsigned_lt(vm, lzx, e_m1)
    # shiftL = min(lz, e_base - 1)   (e_base >= 1 always)
    shiftL = mux_planes(vm, lz_small, lzx, e_m1)
    W = shift_left_var(vm, W, shiftL[:len(lz)])
    e_new, _ = ripple_sub(vm, e_base, shiftL)

    # --- round to nearest even
    g, r = W[2], W[1]
    st = vm.or_(W[0], sticky)
    lsb = W[3]
    inc = vm.and_(g, vm.or_tree([r, st, lsb]))
    Mr, cr = ripple_inc(vm, W[3:3 + mb + 1], inc)
    e_fin, _ = ripple_inc(vm, e_new, cr)  # eb+1 bits
    hidden_out = vm.or_(Mr[mb], cr)
    m_out = mux_planes(vm, cr, zero_planes(vm, mb), Mr[0:mb])
    e_enc = [vm.and_(hidden_out, x) for x in e_fin[:eb]]

    # --- overflow to inf: e_fin >= 2^eb - 1
    ge_max = vm.or_(e_fin[eb], and_tree(vm, e_fin[:eb]))

    # --- zero result (exact cancellation): sign = s_a AND s_b (RNE)
    zero_res = all_zero
    sign_zero = vm.and_(a["s"], b["s"])
    s_res = vm.mux(zero_res, sign_zero, s_l)
    e_enc = mux_planes(vm, zero_res, zero_planes(vm, eb), e_enc)
    m_out = mux_planes(vm, zero_res, zero_planes(vm, mb), m_out)

    normal = _pack_float(vm, s_res, e_enc, m_out, fmt)

    # --- special chain: overflow → Inf, input Inf, NaN
    res_nan = vm.or_tree([a["nan"], b["nan"], vm.and_(vm.and_(a["inf"], b["inf"]), eff_sub)])
    res_inf = vm.and_(vm.or_(a["inf"], b["inf"]), vm.not_(res_nan))
    inf_sign = vm.mux(a["inf"], a["s"], b["s"])

    out = mux_planes(vm, ge_max, _inf_planes(vm, s_l, fmt), normal)
    out = mux_planes(vm, res_inf, _inf_planes(vm, inf_sign, fmt), out)
    out = mux_planes(vm, res_nan, _qnan_planes(vm, fmt), out)
    return out


def float_add(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    """IEEE-754 binary32 addition, RNE, subnormals, ±0, Inf/NaN."""
    return float_add_fmt(vm, A, B, FLOAT32)


def float_sub(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    Bneg = list(B[:31]) + [vm.not_(B[31])]
    return float_add(vm, A, Bneg)


def bf16_add(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    """bfloat16 addition (same netlist as float32, narrower mantissa)."""
    return float_add_fmt(vm, A, B, BFLOAT16)


def bf16_sub(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    """bfloat16 subtraction: addition with B's sign plane inverted."""
    Bneg = list(B[:15]) + [vm.not_(B[15])]
    return float_add_fmt(vm, A, Bneg, BFLOAT16)


def float_mul_fmt(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane],
                  fmt: FloatFormat = FLOAT32):
    """IEEE-754 multiplication for any format: RNE, gradual underflow, Inf/NaN."""
    mb, eb = fmt.m_bits, fmt.e_bits
    pw = 2 * (mb + 1)  # significand product width
    extw = eb + 3  # two's-complement exponent working width
    a = _unpack_float(vm, A, fmt)
    b = _unpack_float(vm, B, fmt)
    s = vm.xor(a["s"], b["s"])

    # --- significand product: (mb+1)×(mb+1) → pw bits (the dominant ~10N² gates)
    P = fixed_mul_unsigned(vm, a["M"], b["M"])

    # --- exponent: E = e_a_eff + e_b_eff - bias, as extw-bit two's complement
    e_sum, c = ripple_add(vm, extend(vm, a["e_eff"], eb + 1), extend(vm, b["e_eff"], eb + 1))
    E = e_sum + [c, vm.const0()]  # extw bits, always >= 0 here
    E, _ = ripple_sub(vm, E, const_planes(vm, fmt.bias, extw))

    # --- normalize: leading one target position pw-2
    cond_top = P[pw - 1]
    W = [vm.mux(cond_top, P[i + 1], P[i]) for i in range(pw - 1)]
    sticky = vm.and_(cond_top, P[0])
    E, _ = ripple_inc(vm, E, cond_top)

    lz, p_zero = leading_zero_count(vm, W)
    lzx = extend(vm, lz, extw)
    e_m1, _ = ripple_sub(vm, E, const_planes(vm, 1, extw))
    e_m1_neg = e_m1[extw - 1]
    lz_small = unsigned_lt(vm, lzx, e_m1)  # valid when e_m1 >= 0
    shiftL = mux_planes(vm, lz_small, lzx, e_m1)
    shiftL = mux_planes(vm, e_m1_neg, zero_planes(vm, extw), shiftL)
    W = shift_left_var(vm, W, shiftL[:len(lz)])
    E, _ = ripple_sub(vm, E, shiftL)

    # --- gradual underflow: if E <= 0 shift right by (1 - E), E := 1
    one_x = const_planes(vm, 1, extw)
    t, _ = ripple_sub(vm, one_x, E)  # 1 - E
    e_le0 = vm.not_(t[extw - 1])  # t >= 0 ⟺ E <= 1; combine with E != 1
    E_is1 = vm.not_(vm.or_tree([vm.xor(x, y) for x, y in zip(E, one_x)]))
    need_den = vm.and_(e_le0, vm.not_(E_is1))
    t_clamped = mux_planes(vm, need_den, t, zero_planes(vm, extw))
    kshift = max(1, (pw - 2).bit_length())  # stages covering 0..2^kshift-1 >= pw-2
    big_t = vm.or_tree(t_clamped[kshift:])  # t >= 2^kshift: all bits out
    lost = vm.or_tree(W)
    W, sticky = shift_right_var(vm, W, t_clamped[:kshift], sticky)
    sticky = vm.or_(sticky, vm.and_(big_t, lost))
    W = mux_planes(vm, big_t, zero_planes(vm, pw - 1), W)
    E = mux_planes(vm, need_den, one_x, E)

    # --- round to nearest even: mantissa = W[mb..pw-2], G/R below, S = rest
    g, r = W[mb - 1], W[mb - 2]
    st = vm.or_(vm.or_tree(W[0:mb - 2]) if mb > 2 else vm.const0(), sticky)
    lsb = W[mb]
    inc = vm.and_(g, vm.or_tree([r, st, lsb]))
    Mr, cr = ripple_inc(vm, W[mb:pw - 1], inc)
    E, _ = ripple_inc(vm, E, cr)
    hidden_out = vm.or_(Mr[mb], cr)
    m_out = mux_planes(vm, cr, zero_planes(vm, mb), Mr[0:mb])
    e_enc = [vm.and_(hidden_out, x) for x in E[:eb]]

    # overflow: E >= 2^eb - 1 (E >= 0 by now)
    ge_max = vm.or_(vm.or_tree(list(E[eb:extw])), and_tree(vm, E[:eb]))

    # exact zero significand product (either input zero)
    zero_sig = vm.and_(p_zero, vm.not_(cond_top))
    e_enc = mux_planes(vm, zero_sig, zero_planes(vm, eb), e_enc)
    m_out = mux_planes(vm, zero_sig, zero_planes(vm, mb), m_out)

    normal = _pack_float(vm, s, e_enc, m_out, fmt)

    res_nan = vm.or_tree([
        a["nan"], b["nan"],
        vm.and_(a["inf"], b["zero"]),
        vm.and_(b["inf"], a["zero"]),
    ])
    res_inf = vm.and_(vm.or_(a["inf"], b["inf"]), vm.not_(res_nan))

    out = mux_planes(vm, ge_max, _inf_planes(vm, s, fmt), normal)
    out = mux_planes(vm, res_inf, _inf_planes(vm, s, fmt), out)
    out = mux_planes(vm, res_nan, _qnan_planes(vm, fmt), out)
    return out


def float_mul(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    """IEEE-754 binary32 multiplication, RNE, gradual underflow, Inf/NaN."""
    return float_mul_fmt(vm, A, B, FLOAT32)


def bf16_mul(vm: PlaneVM, A: Sequence[Plane], B: Sequence[Plane]):
    """bfloat16 multiplication (same netlist as float32, narrower mantissa)."""
    return float_mul_fmt(vm, A, B, BFLOAT16)


# --------------------------------------------------------------------------
# Schedule recording (consumed by the Pallas kernel and the crossbar checks)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One registered op: its PlaneVM builder plus I/O width and dtype metadata.

    ``in_widths(nbits)`` gives the two input plane counts; ``out_width``
    the output plane count — together they define the op's I/O bits, the
    denominator of the paper's compute-complexity metric (so benchmarks
    derive widths from here instead of parsing op-name strings).

    ``arith``/``dtype`` classify the op for the ``repro.pim`` tracer:
    ``arith`` is the abstract operator (``add``/``sub``/``mul``/``div``) and
    ``dtype`` the :class:`~repro.core.bitplanes.PimType` kind it implements
    (``fixed``/``float32``/``bf16``).  Helper netlists that are not a typed
    arithmetic op (e.g. ``fixed_mul_unsigned``) leave them ``None``."""

    builder: Any
    in_widths: Any  # nbits -> (wa, wb)
    out_width: Any  # nbits -> wout
    arith: str | None = None
    dtype: str | None = None


_OP_TABLE = {
    "fixed_add": OpSpec(fixed_add, lambda n: (n, n), lambda n: n,
                        arith="add", dtype="fixed"),
    "fixed_sub": OpSpec(fixed_sub, lambda n: (n, n), lambda n: n,
                        arith="sub", dtype="fixed"),
    "fixed_mul": OpSpec(fixed_mul_signed, lambda n: (n, n), lambda n: 2 * n,
                        arith="mul", dtype="fixed"),
    "fixed_mul_unsigned": OpSpec(
        fixed_mul_unsigned, lambda n: (n, n), lambda n: 2 * n),
    "fixed_div": OpSpec(
        lambda vm, A, B: fixed_div_signed(vm, A, B)[0],
        lambda n: (n, n), lambda n: n, arith="div", dtype="fixed"),
    "float_add": OpSpec(float_add, lambda n: (32, 32), lambda n: 32,
                        arith="add", dtype="float32"),
    "float_sub": OpSpec(float_sub, lambda n: (32, 32), lambda n: 32,
                        arith="sub", dtype="float32"),
    "float_mul": OpSpec(float_mul, lambda n: (32, 32), lambda n: 32,
                        arith="mul", dtype="float32"),
    "float_div": OpSpec(float_div, lambda n: (32, 32), lambda n: 32,
                        arith="div", dtype="float32"),
    "bf16_add": OpSpec(bf16_add, lambda n: (16, 16), lambda n: 16,
                       arith="add", dtype="bf16"),
    "bf16_sub": OpSpec(bf16_sub, lambda n: (16, 16), lambda n: 16,
                       arith="sub", dtype="bf16"),
    "bf16_mul": OpSpec(bf16_mul, lambda n: (16, 16), lambda n: 16,
                       arith="mul", dtype="bf16"),
}

_ARITH_INDEX = {
    (spec.arith, spec.dtype): name
    for name, spec in _OP_TABLE.items() if spec.arith is not None
}


def op_for(arith: str, dtype: str) -> str:
    """The ``_OP_TABLE`` key implementing abstract ``arith`` at ``dtype``
    (a ``PimType.kind``).  Raises ``KeyError`` with the supported set when
    no netlist exists (e.g. bf16 division)."""
    try:
        return _ARITH_INDEX[(arith, dtype)]
    except KeyError:
        raise KeyError(
            f"no netlist for {arith!r} at dtype {dtype!r}; registered: "
            f"{sorted(_ARITH_INDEX)}"
        ) from None


def op_widths(op: str, nbits: int = 32) -> tuple[int, int, int]:
    """(input-a, input-b, output) plane counts of a registered op."""
    spec = _OP_TABLE[op]
    wa, wb = spec.in_widths(nbits)
    return wa, wb, spec.out_width(nbits)


def op_io_bits(op: str, nbits: int = 32) -> int:
    """Input+output bits per element — the CC denominator (paper §3)."""
    return sum(op_widths(op, nbits))


def build_schedule(op: str, nbits: int = 32, compress: bool = True):
    """Record ``op`` into a flat NOR schedule with named I/O columns.

    With ``compress`` the columns are liveness-recycled (via ``ir.lower``)
    so the whole program fits the paper's 1024-column crossbar (operands +
    intermediates)."""
    spec = _OP_TABLE[op]
    wa, wb = spec.in_widths(nbits)
    vm = PlaneVM(mode="record")
    A = [vm.input_plane() for _ in range(wa)]
    B = [vm.input_plane() for _ in range(wb)]
    out = spec.builder(vm, A, B)
    sched = vm.finish_schedule({"a": A, "b": B}, {"out": out})
    if not compress:
        return sched
    from . import ir

    return ir.lower(ir.from_schedule(sched)).to_schedule()


# --------------------------------------------------------------------------
# Gate-count census (used by the cost model and benchmarks)
# --------------------------------------------------------------------------

def count_gates(fn, *plane_widths: int) -> int:
    """Run ``fn`` on a recording VM with fresh inputs of the given widths and
    return the NOR-gate count."""
    vm = PlaneVM(mode="record")
    args = [[vm.input_plane() for _ in range(w)] for w in plane_widths]
    fn(vm, *args)
    return vm.gates


def gate_counts(nbits: int = 32) -> dict[str, int]:
    """Gate counts for the paper's Fig 3 operation set (our netlists).

    Delegates to ``ir.netlist_gate_counts`` so every caller (benchmarks,
    analyzer, simulate) shares the one compile cache."""
    from . import ir

    return ir.netlist_gate_counts(nbits)
