"""PIM-offload analyzer — the paper's Fig 8 criterion as a framework feature.

For any workload (a compiled training/serving step, or a hand-described op
stream) the analyzer computes

* the TPU-side three-term roofline time,
* the modeled digital-PIM execution time (bit-serial element-parallel, with
  either our netlists' gate counts or the paper-calibrated ones),
* the paper's two axes — compute complexity of the dominant arithmetic and
  data reuse (FLOPs/byte) — and the resulting quadrant verdict.

The paper's conclusion (§6) reproduced as executable logic: **PIM wins only
when reuse is low or CC is low**; full-precision CNN/LM *training* (high CC ×
high reuse) stays on the accelerator, while memory-bound *decode* steps are
the PIM-friendly frontier (paper ref [13]).
"""

from __future__ import annotations

import dataclasses

from .costmodel import MEMRISTIVE_PIM, PAPER_GATE_COUNTS, TPU_V5E, PIMConfig, TPUConfig
from .metrics import compute_complexity, machine_balance


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    flops: float  # global FLOPs per step (MAC = 2 FLOPs)
    hbm_bytes: float  # global accelerator HBM traffic per step
    collective_wire_bytes: float = 0.0  # per-device
    dtype_bits: int = 32

    @property
    def reuse(self) -> float:
        """Arithmetic intensity (paper §4's data-reuse axis)."""
        return self.flops / self.hbm_bytes if self.hbm_bytes else float("inf")


def netlist_gate_counts(nbits: int = 32) -> dict[str, int]:
    """Our own netlists' recorded gate counts, keyed like PAPER_GATE_COUNTS.

    Pulled from the ``repro.core.ir`` compile cache (the cost backend), so
    the analyzer, ``simulate`` and the benchmarks all report from the same
    compilation path — pass the result as ``gate_counts=`` to ``analyze`` /
    ``pim_time`` to model our netlists instead of the paper-calibrated ones.
    """
    from . import ir

    return ir.netlist_gate_counts(nbits)


@dataclasses.dataclass(frozen=True)
class OffloadVerdict:
    workload: str
    tpu_time_s: float
    pim_time_s: float
    reuse: float
    cc: float
    reuse_is_low: bool
    cc_is_low: bool
    pim_wins: bool
    speedup: float  # tpu_time / pim_time (>1 ⇒ PIM faster)
    quadrant: str


def pim_time(
    w: Workload,
    pim: PIMConfig = MEMRISTIVE_PIM,
    gate_counts: dict[str, int] | None = None,
) -> float:
    """Bit-serial element-parallel time: FLOPs → add/mul pairs → gate-cycles.

    A MAC is one float add + one float mul; full row-parallel occupancy is
    assumed (upper bound, as in the paper's §5 methodology).

    For a config whose basis is not memristive (``DRAM_PIM``), the MAC cycle
    count is the program-level cost of the **fused** ``a*b + c`` compilation
    (``simulate.mac_cost`` → ``ir.compile_program``, MAJ3/NOT row commands)
    — one composed schedule whose intermediate product planes never leave
    the array, replacing both the paper's clock-scaled parity and the
    separate add+mul dispatch sum.  Passing explicit ``gate_counts`` (e.g.
    the paper-calibrated ones) forces the legacy gates × cycles_per_gate
    path."""
    n_mac = w.flops / 2.0
    if gate_counts is None and pim.basis != "memristive":
        from .simulate import mac_cost

        return n_mac * mac_cost(basis=pim.basis).cycles / (
            pim.total_rows * pim.clock_hz)
    g = gate_counts or PAPER_GATE_COUNTS
    total_gates = n_mac * (g["float32_add"] + g["float32_mul"])
    return total_gates * pim.cycles_per_gate / (pim.total_rows * pim.clock_hz)


def tpu_time(w: Workload, chips: int = 1, tpu: TPUConfig = TPU_V5E) -> float:
    compute = w.flops / (chips * tpu.peak_bf16)
    memory = w.hbm_bytes / (chips * tpu.hbm_bw)
    collective = w.collective_wire_bytes / tpu.ici_bw
    return max(compute, memory, collective)


def analyze(
    w: Workload,
    chips: int = 1,
    pim: PIMConfig = MEMRISTIVE_PIM,
    tpu: TPUConfig = TPU_V5E,
    gate_counts: dict[str, int] | None = None,
) -> OffloadVerdict:
    g = gate_counts or PAPER_GATE_COUNTS
    t_tpu = tpu_time(w, chips, tpu)
    # pass the *original* gate_counts so a non-memristive config takes the
    # basis-native cycle path (g here is only for the CC-axis thresholds)
    t_pim = pim_time(w, pim, gate_counts)
    # dominant arithmetic = fp MAC → mean CC of add+mul at the workload dtype
    cc = compute_complexity(g["float32_add"] + g["float32_mul"], 2 * 3 * w.dtype_bits)
    # thresholds from the paper: reuse is "low" below the machine balance
    # point (memory-bound on the accelerator); CC is "low" at fixed-add scale
    reuse_low = w.reuse < machine_balance(tpu)
    cc_low = cc <= 2 * compute_complexity(g["fixed32_add"], 3 * 32)
    quadrant = f"{'low' if cc_low else 'high'}-CC/{'low' if reuse_low else 'high'}-reuse"
    return OffloadVerdict(
        workload=w.name,
        tpu_time_s=t_tpu,
        pim_time_s=t_pim,
        reuse=w.reuse,
        cc=cc,
        reuse_is_low=reuse_low,
        cc_is_low=cc_low,
        pim_wins=t_pim < t_tpu,
        speedup=t_tpu / t_pim if t_pim else float("inf"),
        quadrant=quadrant,
    )
