"""Analytical cost model for PIM and accelerator configurations (paper §2, Table 1).

Calibration notes (verified against the paper's own Fig 3 numbers):

* memristive rows = 48 GiB · 8 / 1024 cols = 402,653,184; with the 9N-gate
  ripple adder and 2 cycles/gate (MAGIC init+exec) a 32-bit fixed add takes
  576 cycles → 402.65e6 · 333 MHz / 576 = **232.8 TOPS** (paper: 233 TOPS ✓).
* DRAM PIM in the paper uses the same schedules at 0.5 MHz → 0.349 TOPS
  (paper: 0.35 ✓) — that clock-scaled parity is retained only for the
  paper-facing columns.  Our own DRAM numbers come from the ``dram``
  ``LogicBasis``: genuine MAJ3/NOT schedules (``ir.compile_op(...,
  basis="dram")``) costed in AAP/TRA row commands, e.g. the 32-bit fixed add
  lowers to 96 MAJ + 32 NOT = 546 row cycles → 0.369 TOPS — independently
  derived, within 6% of the paper's convention.
* max power = rows · f · E_gate: memristive 402.65e6·333e6·6.4 fJ = **858 W**
  (paper: 860 W ✓); DRAM 402.65e6·0.5e6·391 fJ = **78.7 W** (paper: 80 W ✓).
* paper-calibrated gate counts back-solved from Fig 3 throughputs are kept in
  ``PAPER_GATE_COUNTS`` next to our own netlist counts (``aritpim.gate_counts``),
  so benchmarks can report both columns.
"""

from __future__ import annotations

import dataclasses

GIB = 1024**3


@dataclasses.dataclass(frozen=True)
class PIMConfig:
    name: str
    crossbar_rows: int
    crossbar_cols: int
    mem_bytes: int
    gate_energy_j: float
    clock_hz: float
    cycles_per_gate: int = 2  # MAGIC init + execute (calibrates to Fig 3)
    basis: str = "memristive"  # LogicBasis used for native compilation

    @property
    def num_crossbars(self) -> int:
        bits = self.mem_bytes * 8
        return bits // (self.crossbar_rows * self.crossbar_cols)

    @property
    def total_rows(self) -> int:
        return self.num_crossbars * self.crossbar_rows

    @property
    def bitwise_throughput(self) -> float:
        """Column gates per second across the whole memory (paper §2.2)."""
        return self.total_rows * self.clock_hz

    @property
    def max_power_w(self) -> float:
        return self.total_rows * self.clock_hz * self.gate_energy_j

    # ---- per-op analytics -------------------------------------------------
    def op_latency_cycles(self, gates: int) -> int:
        """Legacy uniform costing (gates × cycles_per_gate) — the paper's
        clock-scaled convention.  Prefer ``op_throughput_cycles`` with the
        per-basis cycle count from ``ir.op_cost(..., basis=self.basis)``."""
        return gates * self.cycles_per_gate

    def op_throughput(self, gates: int) -> float:
        """Vectored ops/second at full occupancy (paper §3)."""
        return self.total_rows * self.clock_hz / self.op_latency_cycles(gates)

    def op_throughput_cycles(self, cycles: int) -> float:
        """Vectored ops/second given a per-basis command-cycle count (the
        independently derived DRAM path; replaces clock-scaled parity)."""
        return self.total_rows * self.clock_hz / cycles

    def report_throughput(self, report) -> float:
        """Vectored dispatches/second from an ``ir.CostReport`` — works for
        single ops and fused multi-op programs alike, using the report's
        per-basis command cycles."""
        return self.op_throughput_cycles(report.cycles)

    def report_parallel_throughput(self, report) -> float:
        """Vectored dispatches/second if every dependency wave of the gate
        DAG fired in one command cycle (``CostReport.parallel_cycles`` =
        ``num_waves``) — the intra-array gate-parallelism bound the serial
        cycle count is compared against."""
        return self.op_throughput_cycles(max(report.parallel_cycles, 1))

    def report_hbm_bytes(self, report, n_elems: int) -> float:
        """HBM bytes one vectored dispatch moves: the report's boundary
        bit-planes × the packed plane size.  The metric multi-op fusion
        shrinks — intermediates of a fused program never cross this line."""
        return report.hbm_planes * n_elems / 8.0

    def op_throughput_per_watt(self, gates: int) -> float:
        return self.op_throughput(gates) / self.max_power_w

    def time_for_ops(self, n_ops: float, gates: int, rows_occupied: int | None = None) -> float:
        """Seconds to execute ``n_ops`` identical vectored ops."""
        rows = self.total_rows if rows_occupied is None else min(rows_occupied, self.total_rows)
        waves = -(-n_ops // rows) if n_ops > rows else 1
        return waves * self.op_latency_cycles(gates) / self.clock_hz


@dataclasses.dataclass(frozen=True)
class GPUConfig:
    name: str
    mem_bw: float  # bytes/s
    peak_fp32: float  # FLOP/s
    peak_fp16: float
    mem_bytes: int
    max_power_w: float

    def membound_throughput(self, bytes_per_op: int) -> float:
        return self.mem_bw / bytes_per_op

    def compute_throughput(self, flops_per_op: float = 1.0, fp16: bool = False) -> float:
        return (self.peak_fp16 if fp16 else self.peak_fp32) / flops_per_op


@dataclasses.dataclass(frozen=True)
class TPUConfig:
    name: str
    peak_bf16: float  # FLOP/s per chip
    hbm_bw: float  # bytes/s per chip
    ici_bw: float  # bytes/s per link
    hbm_bytes: int
    max_power_w: float  # per chip (modeled)


# --------------------------------------------------------------------- zoo
MEMRISTIVE_PIM = PIMConfig(
    name="memristive",
    crossbar_rows=1024,
    crossbar_cols=1024,
    mem_bytes=48 * GIB,
    gate_energy_j=6.4e-15,
    clock_hz=333e6,
)

DRAM_PIM = PIMConfig(
    name="dram",
    crossbar_rows=65536,
    crossbar_cols=1024,
    mem_bytes=48 * GIB,
    gate_energy_j=391e-15,
    clock_hz=0.5e6,
    basis="dram",
)

A6000 = GPUConfig(
    name="A6000",
    mem_bw=768e9,
    peak_fp32=38.7e12,
    peak_fp16=77.4e12,
    mem_bytes=48 * GIB,
    max_power_w=300.0,
)

A100 = GPUConfig(
    name="A100",
    mem_bw=1935e9,
    peak_fp32=19.5e12,
    peak_fp16=312e12,
    mem_bytes=80 * GIB,
    max_power_w=300.0,
)

TPU_V5E = TPUConfig(
    name="tpu_v5e",
    peak_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16 * GIB,
    max_power_w=200.0,
)

# Paper Fig 3 measured GPU throughputs (A6000, 32-bit ops), ops/s.
PAPER_GPU_MEASURED = {
    "fixed32_add": 0.057e12,
    "fixed32_mul": 0.057e12,
    "float32_add": 0.057e12,
    "float32_mul": 0.057e12,
}

# Gate counts back-solved from the paper's Fig 3 PIM throughputs (AritPIM's
# hand-optimized netlists).  Our own netlists (aritpim.gate_counts) are within
# 1.0–2.6x of these; both columns are reported by benchmarks/fig3_arith.py.
PAPER_GATE_COUNTS = {
    "fixed32_add": 288,  # 9N exactly — our netlist matches
    "fixed32_mul": 9059,
    "float32_add": 1995,
    "float32_mul": 5779,
}

# Paper Fig 3 PIM throughputs (ops/s) for direct assertion in tests.
PAPER_PIM_THROUGHPUT = {
    ("memristive", "fixed32_add"): 233e12,
    ("memristive", "fixed32_mul"): 7.4e12,
    ("memristive", "float32_add"): 33.6e12,
    ("memristive", "float32_mul"): 11.6e12,
    ("dram", "fixed32_add"): 0.35e12,
    ("dram", "fixed32_mul"): 0.01e12,
    ("dram", "float32_add"): 0.05e12,
    ("dram", "float32_mul"): 0.02e12,
}
