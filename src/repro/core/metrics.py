"""Paper metrics: compute complexity (§3, ref [12]) and data reuse (§4).

The paper's two-axis criterion (Fig 8):

* **compute complexity** CC = logic gates per I/O bit — low CC favors PIM;
* **data reuse** = FLOPs per byte moved (arithmetic intensity) — high reuse
  lets the accelerator escape the memory wall, erasing PIM's advantage.
"""

from __future__ import annotations

import dataclasses

from .costmodel import GPUConfig, PIMConfig, TPUConfig


def compute_complexity(gates: int, io_bits: int) -> float:
    """Paper §3: number of logic gates performed per input+output bit."""
    return gates / io_bits


def data_reuse_matmul(n: int) -> float:
    """O(n) reuse for n×n matmul: 2n³ FLOPs over 3n² words (paper §4)."""
    return 2 * n**3 / (3 * n**2)


def data_reuse_conv(k: int) -> float:
    """O(k²) reuse for k×k conv on W×H images (paper §4)."""
    return float(k * k)


@dataclasses.dataclass(frozen=True)
class ImprovementPoint:
    """One point of the paper's Fig 4 (CC vs improvement over memory-bound GPU)."""

    op: str
    cc: float
    pim_throughput: float
    gpu_membound: float

    @property
    def improvement(self) -> float:
        return self.pim_throughput / self.gpu_membound


def fig4_points(pim: PIMConfig, gpu: GPUConfig, gate_counts: dict[str, int],
                io_bits: dict[str, int] | None = None) -> list[ImprovementPoint]:
    """Reconstruct Fig 4: inverse relation between CC and PIM/GPU improvement.

    ``io_bits`` maps op name → input+output bits per element; pass the widths
    derived from ``aritpim._OP_TABLE`` metadata (``aritpim.op_io_bits``) as
    ``benchmarks/fig4_cc.py`` does.  Without it a name-parsing fallback
    covers the paper's Fig-3/4 op set."""
    out = []
    for op, gates in sorted(gate_counts.items()):
        if io_bits is not None and op in io_bits:
            bits = io_bits[op]
        else:
            nbits = 32 if "32" in op else 16
            bits = (4 if "mul" in op and "fixed" in op else 3) * nbits
        bytes_per_op = bits // 8
        out.append(
            ImprovementPoint(
                op=op,
                cc=compute_complexity(gates, bits),
                pim_throughput=pim.op_throughput(gates),
                gpu_membound=gpu.membound_throughput(bytes_per_op),
            )
        )
    return out


def accelerator_membound(tpu: TPUConfig, bytes_per_op: int) -> float:
    return tpu.hbm_bw / bytes_per_op


def machine_balance(tpu: TPUConfig) -> float:
    """FLOPs/byte at which compute and memory terms cross (v5e: ~240)."""
    return tpu.peak_bf16 / tpu.hbm_bw
