"""repro.core — the paper's contribution: digital-PIM machine model, AritPIM
bit-serial arithmetic, analytical cost model, CC/reuse metrics, roofline
extraction, and the Fig-8 offload analyzer."""

from . import analyzer, aritpim, bitplanes, costmodel, ir, machine, metrics, roofline, simulate
from .analyzer import OffloadVerdict, Workload, analyze
from .ir import CompiledSchedule, ScheduleIR, compile_op, get_backend, register_backend
from .costmodel import (
    A100,
    A6000,
    DRAM_PIM,
    MEMRISTIVE_PIM,
    PAPER_GATE_COUNTS,
    PAPER_PIM_THROUGHPUT,
    TPU_V5E,
    GPUConfig,
    PIMConfig,
    TPUConfig,
)
from .machine import PlaneVM, Schedule, execute_schedule
from .roofline import RooflineReport, build_report, parse_collectives

__all__ = [
    "analyzer", "aritpim", "bitplanes", "costmodel", "ir", "machine", "metrics",
    "roofline", "simulate", "OffloadVerdict", "Workload", "analyze",
    "CompiledSchedule", "ScheduleIR", "compile_op", "get_backend", "register_backend",
    "A100", "A6000", "DRAM_PIM", "MEMRISTIVE_PIM", "PAPER_GATE_COUNTS",
    "PAPER_PIM_THROUGHPUT", "TPU_V5E", "GPUConfig", "PIMConfig", "TPUConfig",
    "PlaneVM", "Schedule", "execute_schedule", "RooflineReport",
    "build_report", "parse_collectives",
]
