"""Bit-plane packing utilities for the digital-PIM abstract machine.

A *bit-plane* is one column of the abstract crossbar model (paper Fig 1e):
one bit per memory row.  We pack 32 rows into one ``uint32`` word so that a
column-parallel logic gate over ``R`` rows becomes a single bitwise op over
``ceil(R/32)`` words — the TPU-native (lane-packed, VPU-friendly) encoding of
the paper's column operation.

An ``N``-bit number vector is a list of ``N`` planes, LSB first.

:class:`PimType` packages one element type's plane layout (width, packing,
unpacking) so frontends and kernels share a single description instead of
per-dtype boilerplate: ``F32``/``BF16`` for the IEEE formats, ``fixed(n)``
for two's-complement integers.  The ``repro.pim`` tracer picks netlists by
``PimType.kind`` via the ``aritpim.OpSpec`` dtype metadata.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

WORD = 32
UMAX = jnp.uint32(0xFFFFFFFF)


def num_words(n_elems: int) -> int:
    """Words needed to hold one bit from each of ``n_elems`` rows."""
    return (n_elems + WORD - 1) // WORD


def pack_bits(bits) -> jnp.ndarray:
    """Pack a boolean vector ``[n]`` into ``[ceil(n/32)]`` uint32 (LSB-first in word)."""
    bits = jnp.asarray(bits, dtype=jnp.uint32)
    n = bits.shape[0]
    pad = (-n) % WORD
    if pad:
        bits = jnp.concatenate([bits, jnp.zeros((pad,), jnp.uint32)])
    bits = bits.reshape(-1, WORD)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return (bits << shifts).sum(axis=1, dtype=jnp.uint32)


def unpack_bits(words: jnp.ndarray, n_elems: int) -> jnp.ndarray:
    """Inverse of :func:`pack_bits` → bool ``[n_elems]``."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(-1)[:n_elems].astype(bool)


def int_to_planes(x, nbits: int) -> list[jnp.ndarray]:
    """Two's-complement integer vector ``[n]`` → ``nbits`` packed planes (LSB first)."""
    x = jnp.asarray(x)
    ux = x.astype(jnp.uint32) if x.dtype != jnp.uint32 else x
    return [pack_bits((ux >> jnp.uint32(j)) & jnp.uint32(1)) for j in range(nbits)]


def planes_to_int(planes: list[jnp.ndarray], n_elems: int, signed: bool = True) -> jnp.ndarray:
    """``nbits`` packed planes → integer vector ``[n_elems]`` (two's complement)."""
    nbits = len(planes)
    acc = jnp.zeros((n_elems,), jnp.uint32)
    for j, p in enumerate(planes):
        acc = acc | (unpack_bits(p, n_elems).astype(jnp.uint32) << jnp.uint32(j))
    if signed and nbits < 32:
        sign = (acc >> jnp.uint32(nbits - 1)) & jnp.uint32(1)
        ext = jnp.where(sign == 1, (UMAX << jnp.uint32(nbits)), jnp.uint32(0))
        acc = acc | ext
    if signed:
        return acc.astype(jnp.int32)
    return acc


def f32_to_planes(x) -> list[jnp.ndarray]:
    """float32 vector ``[n]`` → 32 packed planes (LSB first: mantissa, exp, sign)."""
    x = jnp.asarray(x, jnp.float32)
    bits = jax_bitcast_u32(x)
    return [pack_bits((bits >> jnp.uint32(j)) & jnp.uint32(1)) for j in range(32)]


def planes_to_f32(planes: list[jnp.ndarray], n_elems: int) -> jnp.ndarray:
    assert len(planes) == 32
    acc = jnp.zeros((n_elems,), jnp.uint32)
    for j, p in enumerate(planes):
        acc = acc | (unpack_bits(p, n_elems).astype(jnp.uint32) << jnp.uint32(j))
    return jax_bitcast_f32(acc)


def bf16_to_planes(x) -> list[jnp.ndarray]:
    """bfloat16 vector ``[n]`` → 16 packed planes (LSB first: mantissa, exp, sign)."""
    import jax

    x = jnp.asarray(x, jnp.bfloat16)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.uint32)
    return [pack_bits((bits >> jnp.uint32(j)) & jnp.uint32(1)) for j in range(16)]


def planes_to_bf16(planes: list[jnp.ndarray], n_elems: int) -> jnp.ndarray:
    import jax

    assert len(planes) == 16
    acc = jnp.zeros((n_elems,), jnp.uint32)
    for j, p in enumerate(planes):
        acc = acc | (unpack_bits(p, n_elems).astype(jnp.uint32) << jnp.uint32(j))
    return jax.lax.bitcast_convert_type(acc.astype(jnp.uint16), jnp.bfloat16)


def jax_bitcast_u32(x: jnp.ndarray) -> jnp.ndarray:
    import jax

    return jax.lax.bitcast_convert_type(x, jnp.uint32)


def jax_bitcast_f32(x: jnp.ndarray) -> jnp.ndarray:
    import jax

    return jax.lax.bitcast_convert_type(x, jnp.float32)


# ---------------------------------------------------------------------------
# Typed plane layouts (consumed by repro.pim and kernels/ops.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PimType:
    """One PIM element type: plane count + pack/unpack + netlist selection.

    ``kind`` is the key the tracer matches against ``aritpim.OpSpec.dtype``
    (``"fixed"`` | ``"float32"`` | ``"bf16"``); ``width`` is planes per
    element (LSB first); ``nbits`` parameterizes width-generic netlists
    (equal to ``width`` for every current format)."""

    name: str
    kind: str
    width: int
    nbits: int

    def cast(self, x) -> jnp.ndarray:
        """Coerce an array to this type's carrier jnp dtype."""
        if self.kind == "float32":
            return jnp.asarray(x, jnp.float32)
        if self.kind == "bf16":
            return jnp.asarray(x, jnp.bfloat16)
        return jnp.asarray(x)  # fixed: keep the caller's integer dtype

    def to_planes(self, x) -> list[jnp.ndarray]:
        """``[n]`` array → ``width`` packed planes (LSB first)."""
        if self.kind == "float32":
            return f32_to_planes(x)
        if self.kind == "bf16":
            return bf16_to_planes(x)
        return int_to_planes(x, self.nbits)

    def from_planes(self, planes: list[jnp.ndarray], n_elems: int) -> jnp.ndarray:
        """Inverse of :meth:`to_planes` (fixed types decode as signed)."""
        assert len(planes) == self.width, (self.name, len(planes), self.width)
        if self.kind == "float32":
            return planes_to_f32(planes, n_elems)
        if self.kind == "bf16":
            return planes_to_bf16(planes, n_elems)
        return planes_to_int(planes, n_elems, signed=True)


F32 = PimType("f32", "float32", 32, 32)
BF16 = PimType("bf16", "bf16", 16, 16)


def fixed(nbits: int) -> PimType:
    """Two's-complement fixed-point type with ``nbits`` planes."""
    assert 1 <= nbits <= 32
    return PimType(f"fixed{nbits}", "fixed", nbits, nbits)


def np_pack_reference(bits: np.ndarray) -> np.ndarray:
    """NumPy oracle for pack_bits (used by tests)."""
    n = bits.shape[0]
    pad = (-n) % WORD
    if pad:
        bits = np.concatenate([bits, np.zeros(pad, dtype=bits.dtype)])
    bits = bits.reshape(-1, WORD).astype(np.uint64)
    shifts = np.arange(WORD, dtype=np.uint64)
    return (bits << shifts).sum(axis=1).astype(np.uint32)
