"""Optimizers built from scratch (no optax in the environment)."""

from .adamw import adamw
from .adafactor import adafactor
from .clip import clip_by_global_norm, global_norm
from .schedule import warmup_cosine

__all__ = ["adamw", "adafactor", "clip_by_global_norm", "global_norm", "warmup_cosine"]
