"""AdamW with fp32 moments (decoupled weight decay)."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def adamw(
    lr: float | Callable = 1e-3,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * g32 * g32
            mhat = m_new / bc1
            vhat = v_new / bc2
            u = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), m_new, v_new

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
