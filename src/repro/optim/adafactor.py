"""Adafactor (factored second moments) — the memory plan for grok-scale
training on a single pod (DESIGN.md §7): ~4 bytes/param of optimizer state
versus AdamW's 8."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .adamw import Optimizer


def adafactor(
    lr: float = 1e-2,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"s": jax.tree.map(one, params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(g.shape):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = vr[..., None] * vc[..., None, :] / jnp.maximum(
                    vr.mean(axis=-1, keepdims=True)[..., None], eps
                )
                u = g32 * jax.lax.rsqrt(denom + eps)
                s_new = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(v + eps)
                s_new = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), s_new

        pairs = jax.tree.map(upd, grads, state["s"], params,
                             is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x))
        updates = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        s = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"s": s, "step": step}

    return Optimizer(init=init, update=update)
