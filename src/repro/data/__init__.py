"""repro.data"""
