"""Deterministic, resumable token pipeline.

Batches are synthesized statelessly from (seed, step) — a counter-based
threefry draw — so a restarted/re-scaled job reproduces the exact token
stream from its checkpointed step with no data-state to persist.  This is
the fault-tolerance-friendly design used by large-scale frameworks for
synthetic/eval streams; a memmap-backed corpus reader with the same
interface is provided for real tokens.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-ish structure so loss can actually decrease on the synthetic set
    structure: float = 0.7


class SyntheticStream:
    """Stateless synthetic LM stream: next_batch(step) is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def next_batch(self, step: int) -> dict:
        c = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        base = jax.random.randint(k1, (c.global_batch, c.seq_len + 1), 0, c.vocab)
        # inject learnable structure: with prob `structure`, token t+1 = f(token t)
        nxt = (base[:, :-1] * 31 + 7) % c.vocab
        use = jax.random.bernoulli(k2, c.structure, nxt.shape)
        seq = base.at[:, 1:].set(jnp.where(use, nxt, base[:, 1:]))
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def host_batch(self, step: int, host_id: int = 0, num_hosts: int = 1) -> dict:
        """Per-host slice (process-sharded input loading)."""
        full = self.next_batch(step)
        per = self.cfg.global_batch // num_hosts
        return jax.tree.map(lambda x: x[host_id * per : (host_id + 1) * per], full)


class MemmapCorpus:
    """Token-file-backed stream with the same stateless interface.

    File: raw int32 tokens.  Batch (step) deterministically indexes
    non-overlapping windows modulo corpus length."""

    def __init__(self, path: str, cfg: DataConfig):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")

    def next_batch(self, step: int) -> dict:
        c = self.cfg
        n = len(self.tokens)
        span = c.seq_len + 1
        out = np.empty((c.global_batch, span), np.int32)
        for b in range(c.global_batch):
            start = ((step * c.global_batch + b) * span) % max(n - span, 1)
            out[b] = self.tokens[start : start + span]
        return {
            "tokens": jnp.asarray(out[:, :-1] % c.vocab),
            "labels": jnp.asarray(out[:, 1:] % c.vocab),
        }


def make_stream(cfg: DataConfig, path: str | None = None):
    return MemmapCorpus(path, cfg) if path else SyntheticStream(cfg)
