"""repro — ConvPIM digital-PIM evaluation framework (see README.md)."""
