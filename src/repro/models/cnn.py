"""The paper's CNN benchmark models (§5): AlexNet, GoogLeNet, ResNet-50.

Pure-JAX functional implementations with analytic FLOP/byte accounting used
by benchmarks/fig6_cnn_infer.py and fig7_cnn_train.py (the PyTorch+Nsight
methodology of the paper maps to jit + cost_analysis here, DESIGN.md §9).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- primitives


def conv2d(x, w, b=None, stride=1, padding="SAME"):
    out = jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        out = out + b
    return out


def maxpool(x, k, stride, padding="VALID"):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1), padding
    )


def avgpool_global(x):
    return x.mean(axis=(1, 2))


def batchnorm(x, p, train: bool):
    if train:
        mean = x.mean(axis=(0, 1, 2))
        var = x.var(axis=(0, 1, 2))
    else:
        mean, var = p["mean"], p["var"]
    inv = jax.lax.rsqrt(var + 1e-5)
    return (x - mean) * inv * p["scale"] + p["bias"]


def _init_conv(key, k, cin, cout):
    fan_in = k * k * cin
    return jax.random.normal(key, (k, k, cin, cout), jnp.float32) * (2.0 / fan_in) ** 0.5


def _init_bn(c):
    return {
        "scale": jnp.ones((c,), jnp.float32),
        "bias": jnp.zeros((c,), jnp.float32),
        "mean": jnp.zeros((c,), jnp.float32),
        "var": jnp.ones((c,), jnp.float32),
    }


def _init_fc(key, cin, cout):
    return {
        "w": jax.random.normal(key, (cin, cout), jnp.float32) * cin ** -0.5,
        "b": jnp.zeros((cout,), jnp.float32),
    }


@dataclasses.dataclass
class FlopCounter:
    flops: float = 0.0
    weight_bytes: float = 0.0
    act_bytes: float = 0.0

    def conv(self, hw_out, k, cin, cout):
        self.flops += 2.0 * hw_out * hw_out * cout * k * k * cin
        self.weight_bytes += 4.0 * k * k * cin * cout

    def fc(self, cin, cout):
        self.flops += 2.0 * cin * cout
        self.weight_bytes += 4.0 * cin * cout


# ------------------------------------------------------------------ AlexNet

ALEXNET = [  # (k, cout, stride, pool_after)
    (11, 96, 4, True), (5, 256, 1, True), (3, 384, 1, False),
    (3, 384, 1, False), (3, 256, 1, True),
]


def init_alexnet(key, n_classes=1000):
    ks = jax.random.split(key, 9)
    p = {"conv": [], "fc": []}
    cin = 3
    for i, (k, cout, s, _) in enumerate(ALEXNET):
        p["conv"].append({"w": _init_conv(ks[i], k, cin, cout), "b": jnp.zeros((cout,))})
        cin = cout
    p["fc"] = [
        _init_fc(ks[5], 256 * 6 * 6, 4096),
        _init_fc(ks[6], 4096, 4096),
        _init_fc(ks[7], 4096, n_classes),
    ]
    return p


def alexnet(p, x, train=False):
    for (k, cout, s, pool), cp in zip(ALEXNET, p["conv"]):
        x = jax.nn.relu(conv2d(x, cp["w"], cp["b"], stride=s, padding="SAME" if k != 11 else [(2, 2), (2, 2)]))
        if pool:
            x = maxpool(x, 3, 2)
    x = x.reshape(x.shape[0], -1)
    for i, fp in enumerate(p["fc"]):
        x = x @ fp["w"] + fp["b"]
        if i < 2:
            x = jax.nn.relu(x)
    return x


# ----------------------------------------------------------------- ResNet50

RESNET50_STAGES = [(3, 64, 256, 1), (4, 128, 512, 2), (6, 256, 1024, 2), (3, 512, 2048, 2)]


def init_resnet50(key, n_classes=1000):
    keys = iter(jax.random.split(key, 200))
    p = {"stem": {"w": _init_conv(next(keys), 7, 3, 64), "bn": _init_bn(64)}, "stages": []}
    cin = 64
    for n_blocks, mid, cout, stride in RESNET50_STAGES:
        blocks = []
        for b in range(n_blocks):
            s = stride if b == 0 else 1
            blk = {
                "conv1": {"w": _init_conv(next(keys), 1, cin, mid), "bn": _init_bn(mid)},
                "conv2": {"w": _init_conv(next(keys), 3, mid, mid), "bn": _init_bn(mid)},
                "conv3": {"w": _init_conv(next(keys), 1, mid, cout), "bn": _init_bn(cout)},
            }
            if b == 0:
                blk["proj"] = {"w": _init_conv(next(keys), 1, cin, cout), "bn": _init_bn(cout)}
            blocks.append(blk)
            cin = cout
        p["stages"].append(blocks)
    p["fc"] = _init_fc(next(keys), 2048, n_classes)
    return p


def resnet50(p, x, train=False):
    x = conv2d(x, p["stem"]["w"], stride=2)
    x = jax.nn.relu(batchnorm(x, p["stem"]["bn"], train))
    x = maxpool(x, 3, 2, padding="SAME")
    for stage, (_, _, _, stage_stride) in zip(p["stages"], RESNET50_STAGES):
        for b, blk in enumerate(stage):
            s = stage_stride if b == 0 else 1
            h = jax.nn.relu(batchnorm(conv2d(x, blk["conv1"]["w"], stride=s), blk["conv1"]["bn"], train))
            h = jax.nn.relu(batchnorm(conv2d(h, blk["conv2"]["w"]), blk["conv2"]["bn"], train))
            h = batchnorm(conv2d(h, blk["conv3"]["w"]), blk["conv3"]["bn"], train)
            if "proj" in blk:
                x = batchnorm(conv2d(x, blk["proj"]["w"], stride=s), blk["proj"]["bn"], train)
            x = jax.nn.relu(x + h)
    x = avgpool_global(x)
    return x @ p["fc"]["w"] + p["fc"]["b"]


# ---------------------------------------------------------------- GoogLeNet

# (1x1, 3x3red, 3x3, 5x5red, 5x5, pool_proj) per inception block
INCEPTION = {
    "3a": (64, 96, 128, 16, 32, 32), "3b": (128, 128, 192, 32, 96, 64),
    "4a": (192, 96, 208, 16, 48, 64), "4b": (160, 112, 224, 24, 64, 64),
    "4c": (128, 128, 256, 24, 64, 64), "4d": (112, 144, 288, 32, 64, 64),
    "4e": (256, 160, 320, 32, 128, 128), "5a": (256, 160, 320, 32, 128, 128),
    "5b": (384, 192, 384, 48, 128, 128),
}


def init_googlenet(key, n_classes=1000):
    keys = iter(jax.random.split(key, 100))
    p = {
        "stem1": {"w": _init_conv(next(keys), 7, 3, 64), "b": jnp.zeros((64,))},
        "stem2": {"w": _init_conv(next(keys), 1, 64, 64), "b": jnp.zeros((64,))},
        "stem3": {"w": _init_conv(next(keys), 3, 64, 192), "b": jnp.zeros((192,))},
        "inception": {},
    }
    cin = 192
    for name, (c1, r3, c3, r5, c5, pp) in INCEPTION.items():
        p["inception"][name] = {
            "b1": {"w": _init_conv(next(keys), 1, cin, c1), "b": jnp.zeros((c1,))},
            "b3r": {"w": _init_conv(next(keys), 1, cin, r3), "b": jnp.zeros((r3,))},
            "b3": {"w": _init_conv(next(keys), 3, r3, c3), "b": jnp.zeros((c3,))},
            "b5r": {"w": _init_conv(next(keys), 1, cin, r5), "b": jnp.zeros((r5,))},
            "b5": {"w": _init_conv(next(keys), 5, r5, c5), "b": jnp.zeros((c5,))},
            "bp": {"w": _init_conv(next(keys), 1, cin, pp), "b": jnp.zeros((pp,))},
        }
        cin = c1 + c3 + c5 + pp
    p["fc"] = _init_fc(next(keys), 1024, n_classes)
    return p


def googlenet(p, x, train=False):
    x = jax.nn.relu(conv2d(x, p["stem1"]["w"], p["stem1"]["b"], stride=2))
    x = maxpool(x, 3, 2, padding="SAME")
    x = jax.nn.relu(conv2d(x, p["stem2"]["w"], p["stem2"]["b"]))
    x = jax.nn.relu(conv2d(x, p["stem3"]["w"], p["stem3"]["b"]))
    x = maxpool(x, 3, 2, padding="SAME")
    for name in INCEPTION:
        q = p["inception"][name]
        b1 = jax.nn.relu(conv2d(x, q["b1"]["w"], q["b1"]["b"]))
        b3 = jax.nn.relu(conv2d(jax.nn.relu(conv2d(x, q["b3r"]["w"], q["b3r"]["b"])), q["b3"]["w"], q["b3"]["b"]))
        b5 = jax.nn.relu(conv2d(jax.nn.relu(conv2d(x, q["b5r"]["w"], q["b5r"]["b"])), q["b5"]["w"], q["b5"]["b"]))
        bp = jax.nn.relu(conv2d(maxpool(x, 3, 1, padding="SAME"), q["bp"]["w"], q["bp"]["b"]))
        x = jnp.concatenate([b1, b3, b5, bp], axis=-1)
        if name in ("3b", "4e"):
            x = maxpool(x, 3, 2, padding="SAME")
    x = avgpool_global(x)
    return x @ p["fc"]["w"] + p["fc"]["b"]


MODELS = {
    "alexnet": (init_alexnet, alexnet),
    "googlenet": (init_googlenet, googlenet),
    "resnet50": (init_resnet50, resnet50),
}


@functools.lru_cache(maxsize=None)
def model_flops(name: str, img: int = 224) -> dict:
    """FLOPs/weight-bytes/activation-bytes per image via jax cost analysis."""
    init, apply = MODELS[name]
    params = init(jax.random.PRNGKey(0))
    x = jax.ShapeDtypeStruct((1, img, img, 3), jnp.float32)
    lowered = jax.jit(lambda p, x: apply(p, x)).lower(params, x)
    cost = lowered.compile().cost_analysis()
    nparams = sum(int(p.size) for p in jax.tree.leaves(params))
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "params": nparams,
    }
