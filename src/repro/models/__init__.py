"""Model substrate: transformer families, SSM/RG-LRU blocks, paper CNNs."""

from . import attention, cnn, layers, moe, rglru, ssm, transformer
