"""Mixture-of-Experts FFN: top-k routing, shared experts, capacity dispatch.

Baseline dispatch is the capacity-slot formulation (scatter → per-expert
batched matmul → gather), which GSPMD can shard either expert-parallel
(deepseek: 64 experts / 16-way model axis) or tensor-parallel on d_ff
(grok: 8 experts < axis size).  The §Perf iterations replace the GSPMD plan
with an explicit shard_map all-to-all where the roofline shows collective
dominance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import cdtype, pdtype


def init_moe(key, cfg: ModelConfig) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    sc_in, sc_out = d ** -0.5, ff ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * sc_in,
        "w_gate": jax.random.normal(ks[1], (E, d, ff), pdtype(cfg)) * sc_in,
        "w_up": jax.random.normal(ks[2], (E, d, ff), pdtype(cfg)) * sc_in,
        "w_down": jax.random.normal(ks[3], (E, ff, d), pdtype(cfg)) * sc_out,
    }
    if cfg.n_shared_experts:
        ffs = ff * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": jax.random.normal(k1, (d, ffs), pdtype(cfg)) * sc_in,
            "w_up": jax.random.normal(k2, (d, ffs), pdtype(cfg)) * sc_in,
            "w_down": jax.random.normal(k3, (ffs, d), pdtype(cfg)) * sc_out,
        }
    return p


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)


def apply_moe(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    """x: [B, T, d] → (out [B, T, d], aux_metrics dict)."""
    dt = cdtype(cfg)
    B, T, d = x.shape
    N = B * T
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(N, cfg)
    xf = x.reshape(N, d)

    # --- routing (fp32 for stable softmax/top-k)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)  # [N, E]
    top_w, top_i = jax.lax.top_k(probs, k)  # [N, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- capacity-slot assignment
    e_flat = top_i.reshape(-1)  # [N*k]
    w_flat = top_w.reshape(-1)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # [N*k, E]
    pos_in_e = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=0) - 1, e_flat[:, None], axis=1
    )[:, 0]
    keep = pos_in_e < C
    slot = e_flat * C + jnp.minimum(pos_in_e, C - 1)  # [N*k]

    tok_of_assign = jnp.arange(N * k) // k
    contrib = jnp.where(keep[:, None], xf[tok_of_assign], 0).astype(dt)
    buf = jnp.zeros((E * C, d), dt).at[slot].add(contrib)
    buf = buf.reshape(E, C, d)

    # --- per-expert FFN (batched over the expert dim; EP-shardable)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt))
    h = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(dt))
    h = h.reshape(E * C, d)

    # --- combine
    gathered = h[slot] * (w_flat * keep).astype(dt)[:, None]  # [N*k, d]
    out = gathered.reshape(N, k, d).sum(axis=1)

    if cfg.n_shared_experts:
        s = p["shared"]
        gs = act(xf.astype(dt) @ s["w_gate"].astype(dt))
        us = xf.astype(dt) @ s["w_up"].astype(dt)
        out = out + (gs * us) @ s["w_down"].astype(dt)

    # --- aux: switch-style load-balance loss + drop fraction
    me = probs.mean(axis=0)  # mean router prob per expert
    ce = (onehot.sum(axis=0) / (N * k)).astype(jnp.float32)  # assignment frac
    aux = {
        "load_balance_loss": E * jnp.sum(me * ce),
        "dropped_frac": 1.0 - keep.mean(),
    }
    return out.reshape(B, T, d), aux
