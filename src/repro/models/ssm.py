"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked algorithm as a ``lax.scan`` over time chunks: within a chunk the
quadratic "attention-like" term runs on the MXU; across chunks a [nh, hp, ds]
state is carried — O(1) decode memory, linear-time prefill.  Single B/C group
(mamba-2 default), gated RMSNorm before the output projection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import cdtype, pdtype, rms_norm


def init_ssm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, ds, nh, w = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.conv_width
    conv_ch = di + 2 * ds
    ks = jax.random.split(key, 4)
    p = {
        # fused in-projection: z (di) | x (di) | B (ds) | C (ds) | dt (nh)
        "w_in": jax.random.normal(ks[0], (d, 2 * di + 2 * ds + nh), pdtype(cfg)) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (w, conv_ch), pdtype(cfg)) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), pdtype(cfg)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.zeros((di,), pdtype(cfg)),
        "w_out": jax.random.normal(ks[2], (di, d), pdtype(cfg)) * di ** -0.5,
    }
    return p


def _split_in(h, cfg: ModelConfig):
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = h[..., :di]
    xin = h[..., di : 2 * di]
    Bc = h[..., 2 * di : 2 * di + ds]
    Cc = h[..., 2 * di + ds : 2 * di + 2 * ds]
    dt = h[..., 2 * di + 2 * ds :]
    return z, xin, Bc, Cc, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv: x [B,T,C], w [W,C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :],  # [W, 1, C]
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NTC", "TIO", "NTC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


def ssm_block(p: dict, x: jnp.ndarray, cfg: ModelConfig, state: dict | None = None):
    """Returns (y [B,T,d], new_state_or_None).

    state (decode): {"conv": [B, W-1, conv_ch], "h": [B, nh, hp, ds]} — pass
    T=1 inputs for one-token decode; T>1 runs the chunked prefill/train path
    (returning the final state when ``state`` is given)."""
    dt_ = cdtype(cfg)
    B, T, _ = x.shape
    di, ds, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_headdim
    W = cfg.conv_width

    hin = x @ p["w_in"].astype(dt_)
    z, xin, Bc, Cc, dtp = _split_in(hin, cfg)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)

    if state is not None and T == 1:
        # ---- one-token decode
        window = jnp.concatenate([state["conv"], conv_in], axis=1)  # [B, W, C]
        conv_out = (window * p["conv_w"].astype(dt_)[None]).sum(1, keepdims=True)
        conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(dt_))
        xin_c, Bc_c, Cc_c = (
            conv_out[..., :di], conv_out[..., di : di + ds], conv_out[..., di + ds :]
        )
        dt = jax.nn.softplus(dtp[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B, nh]
        A = -jnp.exp(p["A_log"])  # [nh]
        dA = jnp.exp(dt * A)  # [B, nh]
        xh = xin_c.reshape(B, nh, hp).astype(jnp.float32)
        Bf = Bc_c[:, 0].astype(jnp.float32)  # [B, ds]
        Cf = Cc_c[:, 0].astype(jnp.float32)
        h_new = dA[..., None, None] * state["h"] + jnp.einsum(
            "bh,bs,bhp->bhps", dt, Bf, xh
        )
        y = jnp.einsum("bs,bhps->bhp", Cf, h_new) + p["D"][None, :, None] * xh
        y = y.reshape(B, 1, di).astype(dt_)
        y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
        out = y @ p["w_out"].astype(dt_)
        return out, {"conv": window[:, 1:], "h": h_new}

    # ---- chunked prefill / train
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_)))
    xin_c = conv_out[..., :di]
    Bc_c = conv_out[..., di : di + ds].astype(jnp.float32)
    Cc_c = conv_out[..., di + ds :].astype(jnp.float32)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])  # [B,T,nh]
    A = -jnp.exp(p["A_log"])
    logdA = dt * A  # [B,T,nh], <= 0

    Q = min(cfg.ssm_chunk, T)
    pad = (-T) % Q
    if pad:
        xin_c = jnp.pad(xin_c, ((0, 0), (0, pad), (0, 0)))
        Bc_c = jnp.pad(Bc_c, ((0, 0), (0, pad), (0, 0)))
        Cc_c = jnp.pad(Cc_c, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        logdA = jnp.pad(logdA, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // Q

    xh = xin_c.reshape(B, nc, Q, nh, hp).astype(jnp.float32)
    Bb = Bc_c.reshape(B, nc, Q, ds)
    Cb = Cc_c.reshape(B, nc, Q, ds)
    dtb = dt.reshape(B, nc, Q, nh)
    lab = logdA.reshape(B, nc, Q, nh)

    # scan over chunks; carry h [B, nh, hp, ds]
    h0 = (
        state["h"].astype(jnp.float32)
        if state is not None
        else jnp.zeros((B, nh, hp, ds), jnp.float32)
    )

    def chunk_step(h, xs):
        xc, Bcc, Ccc, dtc, lac = xs  # [B,Q,...]
        la = jnp.cumsum(lac, axis=1)  # inclusive cumulative log-decay [B,Q,nh]
        # inter-chunk: y_inter[i] = exp(la_i) * C_i · h
        y_inter = jnp.einsum("bqs,bhps->bqhp", Ccc, h) * jnp.exp(la)[..., None]
        # intra-chunk: scores[i,j] = (C_i·B_j) exp(la_i - la_j) dt_j  (j<=i)
        cb = jnp.einsum("bqs,bps->bqp", Ccc, Bcc)  # [B,Q,Q]
        tri = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
        # mask *inside* the exp: la_i - la_j > 0 on the upper triangle would
        # overflow to inf (inf·0 = NaN after tri-masking)
        ldiff = jnp.where(
            tri[None, :, :, None], la[:, :, None, :] - la[:, None, :, :], -jnp.inf
        )
        scores = cb[..., None] * jnp.exp(ldiff) * dtc[:, None, :, :]
        y_intra = jnp.einsum("bqph,bphx->bqhx", scores, xc)
        # state to next chunk: h' = exp(la_last) h + Σ_j exp(la_last-la_j) dt_j B_j⊗x_j
        la_last = la[:, -1:, :]  # [B,1,nh]
        w = jnp.exp(la_last - la) * dtc  # [B,Q,nh]
        h_new = jnp.exp(la_last[:, 0])[:, :, None, None] * h + jnp.einsum(
            "bqh,bqs,bqhp->bhps", w, Bcc, xc
        )
        return h_new, y_inter + y_intra

    hT, yb = jax.lax.scan(
        chunk_step, h0,
        (
            xh.transpose(1, 0, 2, 3, 4),
            Bb.transpose(1, 0, 2, 3),
            Cb.transpose(1, 0, 2, 3),
            dtb.transpose(1, 0, 2, 3),
            lab.transpose(1, 0, 2, 3),
        ),
    )
    y = yb.transpose(1, 0, 2, 3, 4).reshape(B, Tp, nh, hp)[:, :T]
    y = y + p["D"][None, None, :, None] * xh.reshape(B, Tp, nh, hp)[:, :T]
    y = y.reshape(B, T, di).astype(dt_)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["w_out"].astype(dt_)

    new_state = None
    if state is not None:
        conv_tail = conv_in[:, -(W - 1) :] if T >= W - 1 else jnp.concatenate(
            [state["conv"][:, T:], conv_in], axis=1
        )
        new_state = {"conv": conv_tail, "h": hT}
    return out, new_state


def init_ssm_state(cfg: ModelConfig, batch: int) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state), cdtype(cfg)),
        "h": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
    }
