"""Attention: GQA/MQA, local windows, cross-attention, softcap, KV caches.

Training/prefill paths use a double-blocked online-softmax ("flash")
attention written with ``lax.scan`` so activation memory is O(block²) rather
than O(T·S) — required for the 32k prefill cells to fit HBM.  Decode uses a
single fused cache-attention step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import apply_rope, cdtype, pdtype, softcap

NEG_INF = -1e30
POS_SENTINEL = 1 << 30  # key-position pad: fails every validity check


# --------------------------------------------------------------- parameters

def n_heads_eff(cfg: ModelConfig) -> int:
    """Query-head count after optional TP padding (exact numerics: the extra
    heads have zero wq rows and zero wo columns)."""
    return max(cfg.pad_heads_to, cfg.n_heads) if cfg.pad_heads_to else cfg.n_heads


def init_attention(key, cfg: ModelConfig) -> dict:
    d, H, K, hd = cfg.d_model, n_heads_eff(cfg), cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, H * hd), pdtype(cfg)) * sc,
        "wk": jax.random.normal(ks[1], (d, K * hd), pdtype(cfg)) * sc,
        "wv": jax.random.normal(ks[2], (d, K * hd), pdtype(cfg)) * sc,
        "wo": jax.random.normal(ks[3], (H * hd, d), pdtype(cfg)) * ((H * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), pdtype(cfg))
        p["bk"] = jnp.zeros((K * hd,), pdtype(cfg))
        p["bv"] = jnp.zeros((K * hd,), pdtype(cfg))
    return p


def _proj(p, x, cfg: ModelConfig, *, cross_from=None):
    """→ q [B,T,H,hd], k,v [B,S,K,hd]."""
    dt = cdtype(cfg)
    B, T, _ = x.shape
    H, K, hd = n_heads_eff(cfg), cfg.n_kv_heads, cfg.hd
    kv_src = x if cross_from is None else cross_from
    S = kv_src.shape[1]
    q = x @ p["wq"].astype(dt)
    k = kv_src @ p["wk"].astype(dt)
    v = kv_src @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return (
        q.reshape(B, T, H, hd),
        k.reshape(B, S, K, hd),
        v.reshape(B, S, K, hd),
    )


# --------------------------------------------------------- flash attention

def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    attn_softcap: float = 0.0,
    q_positions: jnp.ndarray | None = None,
    kv_positions: jnp.ndarray | None = None,
    bq: int = 512,
    bk: int = 512,
    remat_inner: bool = False,
) -> jnp.ndarray:
    """Double-blocked online-softmax attention.

    q [B,T,H,D]; k,v [B,S,K,D] with H = K·G (GQA).  Positions are absolute
    ([T]/[S] int32); local windows keep keys with qpos-window < kpos <= qpos.
    ``remat_inner`` checkpoints the kv-step so its probability block is
    recomputed in the backward pass (flash-style backward).
    """
    B, T, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = D ** -0.5
    in_dtype = q.dtype

    if q_positions is None:
        q_positions = jnp.arange(T, dtype=jnp.int32)
    if kv_positions is None:
        kv_positions = jnp.arange(S, dtype=jnp.int32)

    bq = min(bq, T)
    bk = min(bk, S)
    padT = (-T) % bq
    padS = (-S) % bk
    if padT:
        q = jnp.pad(q, ((0, 0), (0, padT), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, padT))
    if padS:
        k = jnp.pad(k, ((0, 0), (0, padS), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padS), (0, 0), (0, 0)))
        kv_positions = jnp.pad(
            kv_positions, (0, padS), constant_values=POS_SENTINEL
        )
    Tp, Sp = T + padT, S + padS
    nq, nk = Tp // bq, Sp // bk

    # [nq, B, K, G, bq, D] / [nk, B, K, bk, D]
    qb = q.reshape(B, nq, bq, K, G, D).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(B, nk, bk, K, D).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, bk, K, D).transpose(1, 0, 3, 2, 4)
    qpos_b = q_positions.reshape(nq, bq)
    kpos_b = kv_positions.reshape(nk, bk)

    def one_q_block(_, xs):
        qi, qpos = xs  # [B,K,G,bq,D], [bq]
        o0 = jnp.zeros((B, K, G, bq, D), jnp.float32)
        m0 = jnp.full((B, K, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, bq), jnp.float32)

        def one_kv_block(carry, ys):
            o, m, l = carry
            ki, vi, kpos = ys  # [B,K,bk,D], [bk]
            s = jnp.einsum(
                "bkgqd,bksd->bkgqs", qi, ki, preferred_element_type=jnp.float32
            ) * scale
            if attn_softcap:
                s = attn_softcap * jnp.tanh(s / attn_softcap)
            valid = kpos[None, :] < POS_SENTINEL // 2
            if causal:
                valid = valid & (kpos[None, :] <= qpos[:, None])
            if window:
                valid = valid & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(valid[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pmat = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + pmat.sum(axis=-1)
            o_new = o * alpha[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", pmat.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32,
            )
            return (o_new, m_new, l_new), None

        if remat_inner:
            one_kv_block = jax.checkpoint(one_kv_block)
        (o, m, l), _ = jax.lax.scan(one_kv_block, (o0, m0, l0), (kb, vb, kpos_b))
        o = o / jnp.maximum(l, 1e-20)[..., None]
        return None, o.astype(in_dtype)

    _, ob = jax.lax.scan(one_q_block, None, (qb, qpos_b))
    # [nq, B, K, G, bq, D] → [B, T, H, D]
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(B, Tp, H, D)
    return out[:, :T]


# ----------------------------------------------------------------- decode

def decode_attention(
    q: jnp.ndarray,  # [B, 1, H, D]
    k_cache: jnp.ndarray,  # [B, S, K, D]
    v_cache: jnp.ndarray,
    kv_positions: jnp.ndarray,  # [S] absolute positions (-1 = empty)
    position: jnp.ndarray,  # scalar: current decode position
    *,
    window: int = 0,
    attn_softcap: float = 0.0,
) -> jnp.ndarray:
    B, _, H, D = q.shape
    K = k_cache.shape[2]
    G = H // K
    scale = D ** -0.5
    qh = q.reshape(B, K, G, D)
    s = jnp.einsum(
        "bkgd,bskd->bkgs", qh, k_cache, preferred_element_type=jnp.float32
    ) * scale
    if attn_softcap:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    valid = (kv_positions >= 0) & (kv_positions <= position)
    if window:
        valid = valid & (kv_positions > position - window)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ------------------------------------------------------------ full blocks

def attention_block(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    kind: str,  # global | local | cross
    positions: jnp.ndarray,
    *,
    cross_embeds: jnp.ndarray | None = None,
    cache: dict | None = None,
    decode_pos: jnp.ndarray | None = None,
):
    """Returns (out, new_cache_entry_or_None).

    Train/prefill: cache is None → flash path (a fresh cache entry is built
    when ``decode_pos is None`` and the caller asked via cache={} sentinel).
    Decode: cache holds {k, v, pos} (self) and x is [B, 1, d].
    """
    dt = cdtype(cfg)
    B, T, _ = x.shape
    window = cfg.window if kind == "local" else 0

    if cache is not None and decode_pos is not None and kind != "cross":
        # ---- one-token decode against the ring cache
        q, k_new, v_new = _proj(p, x, cfg)
        q = apply_rope(q, decode_pos[None, None].astype(jnp.int32) * jnp.ones((B, 1), jnp.int32), cfg.rope_theta)
        k_new = apply_rope(k_new, decode_pos[None, None] * jnp.ones((B, 1), jnp.int32), cfg.rope_theta)
        S_c = cache["k"].shape[1]
        slot = (decode_pos % S_c).astype(jnp.int32)
        if cfg.opt_kv_quant:
            # int8 KV: symmetric per-(token, head) scales; the dequant fuses
            # into the attention dots on TPU → HBM reads int8, not bf16
            kq, ksc = _quant_kv(k_new)
            vq, vsc = _quant_kv(v_new)
            k_cache = jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0))
            k_sc = jax.lax.dynamic_update_slice(cache["k_scale"], ksc, (0, slot, 0))
            v_sc = jax.lax.dynamic_update_slice(cache["v_scale"], vsc, (0, slot, 0))
            k_att = k_cache.astype(dt) * k_sc[..., None].astype(dt)
            v_att = v_cache.astype(dt) * v_sc[..., None].astype(dt)
            new_cache = {"k": k_cache, "v": v_cache, "k_scale": k_sc, "v_scale": v_sc}
        else:
            k_att = k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
            v_att = v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
            new_cache = {"k": k_cache, "v": v_cache}
        pos_cache = jax.lax.dynamic_update_slice(
            cache["pos"], decode_pos[None].astype(jnp.int32), (slot,)
        )
        new_cache["pos"] = pos_cache
        o = decode_attention(
            q, k_att, v_att, pos_cache, decode_pos,
            window=window, attn_softcap=cfg.attn_softcap,
        )
        out = o.reshape(B, T, -1) @ p["wo"].astype(dt)
        return out, new_cache

    if kind == "cross":
        assert cross_embeds is not None
        q, k, v = _proj(p, x, cfg, cross_from=cross_embeds.astype(dt))
        o = flash_attention(
            q, k, v, causal=False, attn_softcap=cfg.attn_softcap,
            q_positions=positions,
            kv_positions=jnp.arange(k.shape[1], dtype=jnp.int32),
            remat_inner=cfg.opt_flash_remat, bq=cfg.attn_bq, bk=cfg.attn_bk,
        )
        out = o.reshape(B, T, -1) @ p["wo"].astype(dt)
        return out, None  # cross kv is recomputed per step (see DESIGN.md)

    # ---- training / prefill self-attention
    q, k, v = _proj(p, x, cfg)
    q = apply_rope(q, positions[None, :] * jnp.ones((B, 1), jnp.int32), cfg.rope_theta)
    k = apply_rope(k, positions[None, :] * jnp.ones((B, 1), jnp.int32), cfg.rope_theta)
    k_att, v_att = k, v
    if cfg.opt_attn_layout and n_heads_eff(cfg) != cfg.n_kv_heads:
        # head-aligned layout: repeating KV keeps every einsum dim sharded
        # like q's heads — GSPMD stops resharding inside the flash blocks
        g = n_heads_eff(cfg) // cfg.n_kv_heads
        k_att = jnp.repeat(k, g, axis=2)
        v_att = jnp.repeat(v, g, axis=2)
    o = flash_attention(
        q, k_att, v_att, causal=True, window=window, attn_softcap=cfg.attn_softcap,
        q_positions=positions, kv_positions=positions,
        remat_inner=cfg.opt_flash_remat, bq=cfg.attn_bq, bk=cfg.attn_bk,
    )
    out = o.reshape(B, T, -1) @ p["wo"].astype(dt)

    new_cache = None
    if cache is not None:  # prefill: populate the cache
        S_c = cache["k"].shape[1]
        if T >= S_c:
            k_w, v_w = k[:, -S_c:], v[:, -S_c:]
            pos_w = positions[-S_c:]
            slots = (pos_w % S_c).astype(jnp.int32)
        else:
            k_w, v_w, pos_w = k, v, positions
            slots = (pos_w % S_c).astype(jnp.int32)
        pos_cache = cache["pos"].at[slots].set(pos_w.astype(jnp.int32))
        if cfg.opt_kv_quant:
            kq, ksc = _quant_kv(k_w)
            vq, vsc = _quant_kv(v_w)
            new_cache = {
                "k": cache["k"].at[:, slots].set(kq),
                "v": cache["v"].at[:, slots].set(vq),
                "k_scale": cache["k_scale"].at[:, slots].set(ksc),
                "v_scale": cache["v_scale"].at[:, slots].set(vsc),
                "pos": pos_cache,
            }
        else:
            new_cache = {
                "k": cache["k"].at[:, slots].set(k_w),
                "v": cache["v"].at[:, slots].set(v_w),
                "pos": pos_cache,
            }
    return out, new_cache


def _quant_kv(x):
    """x [B, T, K, hd] → (int8 [B,T,K,hd], scales f32 [B,T,K])."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1), 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def init_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int) -> dict:
    """Zeroed ring cache for one attention layer."""
    S_c = min(seq_len, cfg.window) if kind == "local" else seq_len
    K, hd = cfg.n_kv_heads, cfg.hd
    dt = cdtype(cfg)
    if cfg.opt_kv_quant:
        return {
            "k": jnp.zeros((batch, S_c, K, hd), jnp.int8),
            "v": jnp.zeros((batch, S_c, K, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, S_c, K), jnp.float32),
            "v_scale": jnp.zeros((batch, S_c, K), jnp.float32),
            "pos": jnp.full((S_c,), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, S_c, K, hd), dt),
        "v": jnp.zeros((batch, S_c, K, hd), dt),
        "pos": jnp.full((S_c,), -1, jnp.int32),
    }
