"""Model assembly for all assigned families (dense/moe/ssm/hybrid/vlm/audio).

Layers are grouped into the config's repeating *unit* pattern and scanned
with stacked parameters (compile time O(1) in depth — grok's 64 layers lower
as one scan).  A partial tail (e.g. recurrentgemma's 38 = 12×3 + 2) is
applied unrolled.  Every layer kind returns an optional cache entry so the
same assembly serves train, prefill and decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import (
    apply_mlp,
    cdtype,
    embed_tokens,
    init_embed,
    init_mlp,
    init_rms_norm,
    lm_head,
    pdtype,
    rms_norm,
)

ATTN_KINDS = ("global", "local", "cross", "moe")


# ------------------------------------------------------------------- init

def init_layer(key, kind: str, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    dt = pdtype(cfg)
    if kind in ("global", "local", "cross"):
        return {
            "attn_norm": init_rms_norm(d, dt),
            "attn": attn_mod.init_attention(ks[0], cfg),
            "mlp_norm": init_rms_norm(d, dt),
            "mlp": init_mlp(ks[1], cfg),
        }
    if kind == "moe":
        return {
            "attn_norm": init_rms_norm(d, dt),
            "attn": attn_mod.init_attention(ks[0], cfg),
            "mlp_norm": init_rms_norm(d, dt),
            "moe": moe_mod.init_moe(ks[1], cfg),
        }
    if kind == "ssm":
        return {"norm": init_rms_norm(d, dt), "ssm": ssm_mod.init_ssm(ks[0], cfg)}
    if kind == "rec":
        return {
            "norm": init_rms_norm(d, dt),
            "rec": rglru_mod.init_rglru(ks[0], cfg),
            "mlp_norm": init_rms_norm(d, dt),
            "mlp": init_mlp(ks[1], cfg),
        }
    raise ValueError(kind)


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 2 + len(cfg.unit) + len(cfg.tail))
    params = {"embed": init_embed(keys[0], cfg), "final_norm": init_rms_norm(cfg.d_model, pdtype(cfg))}
    units = []
    for pos, kind in enumerate(cfg.unit):
        pos_keys = jax.random.split(keys[1 + pos], cfg.n_units)
        units.append(jax.vmap(lambda k, kd=kind: init_layer(k, kd, cfg))(pos_keys))
    params["units"] = units
    params["tail"] = [
        init_layer(keys[1 + len(cfg.unit) + i], kind, cfg)
        for i, kind in enumerate(cfg.tail)
    ]
    return params


# ------------------------------------------------------------------ caches

def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int):
    if kind in ("global", "local", "moe"):
        return attn_mod.init_cache(cfg, "local" if kind == "local" else "global", batch, seq_len)
    if kind == "ssm":
        return ssm_mod.init_ssm_state(cfg, batch)
    if kind == "rec":
        return rglru_mod.init_rglru_state(cfg, batch)
    return {}  # cross: kv recomputed from cross_embeds


def init_caches(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    units = []
    for kind in cfg.unit:
        one = init_layer_cache(cfg, kind, batch, seq_len)
        units.append(
            jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_units,) + x.shape).copy(), one
            )
        )
    tail = [init_layer_cache(cfg, kind, batch, seq_len) for kind in cfg.tail]
    return {"units": units, "tail": tail}


# ------------------------------------------------------------------ layers

def apply_layer(
    p: dict,
    x: jnp.ndarray,
    kind: str,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    *,
    cross_embeds=None,
    cache=None,
    decode_pos=None,
):
    """Pre-norm residual block.  Returns (x, new_cache, aux)."""
    aux = {}
    if kind in ("global", "local", "cross", "moe"):
        a_kind = "global" if kind == "moe" else kind
        h, new_cache = attn_mod.attention_block(
            p["attn"], rms_norm(x, p["attn_norm"], cfg.norm_eps), cfg, a_kind,
            positions, cross_embeds=cross_embeds, cache=cache, decode_pos=decode_pos,
        )
        x = x + h
        hn = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        if kind == "moe":
            h2, aux = moe_mod.apply_moe(p["moe"], hn, cfg)
        else:
            h2 = apply_mlp(p["mlp"], hn, cfg)
        return x + h2, new_cache, aux
    if kind == "ssm":
        h, new_state = ssm_mod.ssm_block(
            p["ssm"], rms_norm(x, p["norm"], cfg.norm_eps), cfg, state=cache
        )
        return x + h, new_state, aux
    if kind == "rec":
        h, new_state = rglru_mod.rglru_block(
            p["rec"], rms_norm(x, p["norm"], cfg.norm_eps), cfg, state=cache
        )
        x = x + h
        h2 = apply_mlp(p["mlp"], rms_norm(x, p["mlp_norm"], cfg.norm_eps), cfg)
        return x + h2, new_state, aux
    raise ValueError(kind)


# ----------------------------------------------------------------- forward

def forward(
    params: dict,
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    *,
    cross_embeds=None,
    caches: dict | None = None,
    decode_pos=None,
    start_pos: int = 0,
):
    """→ (logits [B,T,V], aux, new_caches_or_None)."""
    B, T = tokens.shape
    x = embed_tokens(params["embed"], tokens, cfg)
    if decode_pos is not None:
        positions = jnp.full((T,), 0, jnp.int32)  # unused in decode path
    else:
        positions = jnp.arange(T, dtype=jnp.int32) + start_pos

    use_cache = caches is not None
    unit = cfg.unit

    def unit_fn(carry, xs):
        x, lb = carry
        if use_cache:
            p_list, c_list = xs
        else:
            p_list, c_list = xs, [None] * len(unit)
        new_entries = []
        for pos, kind in enumerate(unit):
            x, nc, aux = apply_layer(
                p_list[pos], x, kind, cfg, positions,
                cross_embeds=cross_embeds, cache=c_list[pos], decode_pos=decode_pos,
            )
            new_entries.append(nc if nc is not None else {})
            lb = lb + aux.get("load_balance_loss", 0.0)
        if use_cache:
            return (x, lb), tuple(new_entries)
        return (x, lb), 0

    if cfg.remat != "none":
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat == "dots"
            else None
        )
        unit_fn = jax.checkpoint(unit_fn, policy=policy, prevent_cse=False)

    lb0 = jnp.zeros((), jnp.float32)
    if use_cache:
        xs = (tuple(params["units"]), tuple(caches["units"]))
    else:
        xs = tuple(params["units"])
    if cfg.unroll_layers:
        carry = (x, lb0)
        ys_list = []
        for i in range(cfg.n_units):
            xs_i = jax.tree.map(lambda t: t[i], xs)
            carry, y = unit_fn(carry, xs_i)
            ys_list.append(y)
        (x, lb) = carry
        if use_cache:
            ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys_list)
        else:
            ys = 0
    else:
        (x, lb), ys = jax.lax.scan(unit_fn, (x, lb0), xs)

    new_caches = None
    if use_cache:
        new_units = list(ys)
        new_tail = []
        for i, kind in enumerate(cfg.tail):
            x, nc, aux = apply_layer(
                params["tail"][i], x, kind, cfg, positions,
                cross_embeds=cross_embeds, cache=caches["tail"][i], decode_pos=decode_pos,
            )
            new_tail.append(nc if nc is not None else {})
            lb = lb + aux.get("load_balance_loss", 0.0)
        new_caches = {"units": new_units, "tail": new_tail}
    else:
        for i, kind in enumerate(cfg.tail):
            x, _, aux = apply_layer(
                params["tail"][i], x, kind, cfg, positions,
                cross_embeds=cross_embeds,
            )
            lb = lb + aux.get("load_balance_loss", 0.0)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head(params["embed"], x, cfg)
    return logits, {"load_balance_loss": lb}, new_caches


# ------------------------------------------------------------------- loss

def loss_fn(params, batch: dict, cfg: ModelConfig, lb_coef: float = 0.01):
    logits, aux, _ = forward(
        params, batch["tokens"], cfg, cross_embeds=batch.get("cross_embeds")
    )
    labels = batch["labels"]
    valid = (labels >= 0).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    ce = -(ll * valid).sum() / jnp.maximum(valid.sum(), 1.0)
    loss = ce + lb_coef * aux["load_balance_loss"]
    return loss, {"ce": ce, **aux}


# ----------------------------------------------------------------- serving

def prefill(params, tokens, cfg: ModelConfig, caches, *, cross_embeds=None):
    """Run the prompt through the model, filling caches.  Returns
    (last-token logits [B,V], new_caches)."""
    logits, _, new_caches = forward(
        params, tokens, cfg, cross_embeds=cross_embeds, caches=caches
    )
    return logits[:, -1], new_caches


def decode_step(params, tokens, position, cfg: ModelConfig, caches, *, cross_embeds=None):
    """One-token decode: tokens [B,1], position scalar int32.  Returns
    (logits [B,V], new_caches)."""
    logits, _, new_caches = forward(
        params, tokens, cfg, cross_embeds=cross_embeds, caches=caches,
        decode_pos=position.astype(jnp.int32),
    )
    return logits[:, -1], new_caches
