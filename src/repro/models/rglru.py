"""RG-LRU recurrent block (Griffin / RecurrentGemma [arXiv:2402.19427]).

Gated linear recurrence h_t = a_t ⊙ h_{t-1} + √(1-a_t²) ⊙ (i_t ⊙ x_t) with
a_t = exp(-c·softplus(Λ)·r_t); prefill/train uses ``associative_scan``
(log-depth), decode carries a [B, w] state — O(1) per token, which is what
makes the 500k-context cell feasible (DESIGN.md §6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import cdtype, pdtype

_C = 8.0  # Griffin's recurrence-gate sharpness constant


def init_rglru(key, cfg: ModelConfig) -> dict:
    d, w, W = cfg.d_model, cfg.lru_dim, cfg.conv_width
    ks = jax.random.split(key, 6)
    sc = d ** -0.5
    return {
        "w_x": jax.random.normal(ks[0], (d, w), pdtype(cfg)) * sc,
        "w_y": jax.random.normal(ks[1], (d, w), pdtype(cfg)) * sc,
        "conv_w": jax.random.normal(ks[2], (W, w), pdtype(cfg)) * 0.1,
        "conv_b": jnp.zeros((w,), pdtype(cfg)),
        "w_r": jax.random.normal(ks[3], (w, w), jnp.float32) * w ** -0.5,
        "w_i": jax.random.normal(ks[4], (w, w), jnp.float32) * w ** -0.5,
        # Λ init so that a^c ∈ (0.9, 0.999) at r=1 (Griffin appendix)
        "lam": jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)),
        "w_out": jax.random.normal(ks[5], (w, d), pdtype(cfg)) * w ** -0.5,
    }


def _gates(p, xc):
    """xc [..., w] fp32 → (log_a, gated_input_scale)."""
    r = jax.nn.sigmoid(xc @ p["w_r"])
    i = jax.nn.sigmoid(xc @ p["w_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r  # [..., w], <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i


def rglru_block(p: dict, x: jnp.ndarray, cfg: ModelConfig, state: dict | None = None):
    """Returns (y [B,T,d], new_state_or_None).

    state (decode): {"conv": [B, W-1, w], "h": [B, w]}."""
    dt_ = cdtype(cfg)
    B, T, _ = x.shape
    w, W = cfg.lru_dim, cfg.conv_width

    xb = x @ p["w_x"].astype(dt_)  # recurrent branch
    yb = jax.nn.gelu(x @ p["w_y"].astype(dt_))  # gate branch

    if state is not None and T == 1:
        window = jnp.concatenate([state["conv"], xb], axis=1)  # [B, W, w]
        xc = (window * p["conv_w"].astype(dt_)[None]).sum(1) + p["conv_b"].astype(dt_)
        xc = xc.astype(jnp.float32)
        a, scale = _gates(p, xc)
        h = a * state["h"] + scale * xc
        out = (h.astype(dt_)[:, None] * yb) @ p["w_out"].astype(dt_)
        return out, {"conv": window[:, 1:], "h": h}

    # prefill / train: causal depthwise conv then associative scan over T
    xp = jnp.pad(xb, ((0, 0), (W - 1, 0), (0, 0)))
    if state is not None:
        xp = jax.lax.dynamic_update_slice(xp, state["conv"], (0, 0, 0))
    xc = jax.lax.conv_general_dilated(
        xp, p["conv_w"].astype(dt_)[:, None, :], (1,), "VALID",
        dimension_numbers=("NTC", "TIO", "NTC"), feature_group_count=w,
    ) + p["conv_b"].astype(dt_)
    xc32 = xc.astype(jnp.float32)
    a, scale = _gates(p, xc32)
    b = scale * xc32
    if state is not None:
        # fold the carried h into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * state["h"])

    def compose(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(compose, (a, b), axis=1)
    out = (h.astype(dt_) * yb) @ p["w_out"].astype(dt_)

    new_state = None
    if state is not None:
        conv_tail = xb[:, -(W - 1):] if T >= W - 1 else jnp.concatenate(
            [state["conv"][:, T:], xb], axis=1
        )
        new_state = {"conv": conv_tail, "h": h[:, -1]}
    return out, new_state


def init_rglru_state(cfg: ModelConfig, batch: int) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_dim), cdtype(cfg)),
        "h": jnp.zeros((batch, cfg.lru_dim), jnp.float32),
    }
