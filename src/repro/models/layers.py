"""Shared neural-net building blocks (pure-functional, no framework deps)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------- norms

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int, dtype) -> jnp.ndarray:
    # stored as a delta around 1 (gemma convention; works for all)
    return jnp.zeros((d,), dtype)


# ---------------------------------------------------------------- softcap

def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """gemma-2 logit soft-capping: cap·tanh(x/cap)."""
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------- rotary

def rope_frequencies(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., T, n, hd]; positions: broadcastable to [..., T]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, hd/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- mlp

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d ** -0.5
    scale_out = ff ** -0.5
    p = {
        "w_up": jax.random.normal(k1, (d, ff), pdtype(cfg)) * scale_in,
        "w_down": jax.random.normal(k2, (ff, d), pdtype(cfg)) * scale_out,
    }
    if cfg.mlp_gated:
        p["w_gate"] = jax.random.normal(k3, (d, ff), pdtype(cfg)) * scale_in
    return p


def apply_mlp(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt = cdtype(cfg)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    up = x @ p["w_up"].astype(dt)
    if cfg.mlp_gated:
        gate = act(x @ p["w_gate"].astype(dt))
        h = gate * up
    else:
        h = act(up)
    return h @ p["w_down"].astype(dt)


# ---------------------------------------------------------------- embedding

def init_embed(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": jax.random.normal(k1, (cfg.vocab, cfg.d_model), pdtype(cfg)) * 0.02}
    if not cfg.tie_embeddings:
        p["head"] = jax.random.normal(k2, (cfg.d_model, cfg.vocab), pdtype(cfg)) * (
            cfg.d_model ** -0.5
        )
    return p


def embed_tokens(p: dict, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = jnp.take(p["tok"].astype(cdtype(cfg)), tokens, axis=0)
    # gemma-style sqrt(d) scaling keeps rms ~1 under tied embeddings
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdtype(cfg))
    return x


def lm_head(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = x @ p["tok"].astype(cdtype(cfg)).T
    else:
        logits = x @ p["head"].astype(cdtype(cfg))
    return softcap(logits, cfg.final_softcap)
