"""Serving driver: batched prefill + decode with temperature sampling.

The same two jitted steps the decode/prefill dry-run cells lower are driven
here against real (smoke-scale) weights.  Includes a toy continuous-batching
queue: requests join at prefill, generate until their stop length, and slots
are recycled — the scheduling skeleton a production server needs.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tfm
from repro.parallel import sharding


@dataclasses.dataclass
class ServeEngine:
    cfg: object
    params: dict
    prefill_fn: object
    decode_fn: object
    max_seq: int

    @classmethod
    def build(cls, cfg, mesh, max_seq: int, seed: int = 0):
        scfg = steps_mod.serve_config(cfg)
        with_cross = scfg.family == "vlm"
        params = tfm.init_params(jax.random.PRNGKey(seed), scfg)
        p_spec = sharding.to_named(sharding.param_specs(params, scfg), mesh)
        params = jax.device_put(params, p_spec)
        prefill_fn = jax.jit(steps_mod.make_prefill_step(scfg, with_cross=with_cross))
        decode_fn = jax.jit(steps_mod.make_decode_step(scfg, with_cross=with_cross))
        return cls(cfg=scfg, params=params, prefill_fn=prefill_fn,
                   decode_fn=decode_fn, max_seq=max_seq)

    def generate(self, prompts: np.ndarray, gen_len: int, *, temperature: float = 1.0,
                 seed: int = 0, cross_embeds=None):
        """prompts: [B, P] int32.  Returns [B, P+gen_len]."""
        B, P = prompts.shape
        caches = tfm.init_caches(self.cfg, B, self.max_seq)
        extra = (cross_embeds,) if cross_embeds is not None else ()
        logits, caches = self.prefill_fn(self.params, jnp.asarray(prompts), caches, *extra)
        key = jax.random.PRNGKey(seed)
        out = [jnp.asarray(prompts)]
        tok = _sample(logits, key, temperature)
        for i in range(gen_len):
            out.append(tok)
            if i == gen_len - 1:
                break
            pos = jnp.asarray(P + i, jnp.int32)
            logits, caches = self.decode_fn(self.params, tok, pos, caches, *extra)
            key = jax.random.fold_in(key, i)
            tok = _sample(logits, key, temperature)
        return np.asarray(jnp.concatenate(out, axis=1))


def _sample(logits, key, temperature):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1)[:, None].astype(jnp.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    engine = ServeEngine.build(cfg, mesh, max_seq=args.prompt_len + args.gen)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = engine.generate(prompts, args.gen, temperature=args.temperature)
    dt = time.time() - t0
    tput = args.batch * args.gen / dt
    print(f"[serve] {cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({tput:.1f} tok/s decode throughput)")
    print("[serve] sample:", out[0, -args.gen:].tolist())
    return out


if __name__ == "__main__":
    main()
