import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede any other import — jax locks the device
count at first init.  The dry-run proves the distribution config is coherent
(sharding propagates, collectives legal, memory fits) without hardware, and
emits the cost/memory/collective numbers the §Roofline analysis consumes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, input_specs
from repro.configs.registry import ARCHS
from repro.core.roofline import build_report, parse_collectives
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes, num_chips
from repro.parallel import sharding

SKIPS: dict[tuple[str, str], str] = {}
for _a in ARCHS:
    _c = get_config(_a)
    if not _c.supports_long_context:
        SKIPS[(_a, "long_500k")] = (
            "full-attention arch: 500k decode requires sub-quadratic "
            "attention (DESIGN.md §6)"
        )


def _spec_tree(tree, mesh, spec_builder):
    return sharding.to_named(spec_builder(tree), mesh)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, optimizer_name: str = "auto",
               fsdp: str = "auto", extra_cfg: dict | None = None):
    """Lower + compile one cell.  Returns (record dict, lowered, compiled)."""
    cfg = get_config(arch)
    # Exact cost accounting needs unrolled layers (XLA counts scan bodies
    # once).  The single-pod pass feeds the §Roofline table → unroll; the
    # multi-pod pass proves the pod-axis sharding compiles → keep the scan
    # (8× faster on this 1-core container, numbers not used for the table).
    cfg = dataclasses.replace(cfg, unroll_layers=not multi_pod)
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    cell = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = num_chips(mesh)
    use_fsdp = (cfg.param_count() > 5e9) if fsdp == "auto" else (fsdp == "on")

    t0 = time.time()
    if cell.kind == "train":
        opt_name, optimizer = steps_mod.choose_optimizer(cfg, optimizer_name)
        p_shapes = steps_mod.param_shapes(cfg)
        o_shapes = steps_mod.opt_state_shapes(optimizer, p_shapes)
        batch = dict(input_specs(cfg, cell))
        batch.setdefault("labels", batch["tokens"])
        p_spec = _spec_tree(p_shapes, mesh, lambda t: sharding.param_specs(t, cfg, fsdp=use_fsdp, mesh=mesh))
        o_spec = _spec_tree(o_shapes, mesh, lambda t: sharding.param_specs(t, cfg, fsdp=use_fsdp, mesh=mesh))
        b_axes = ("data", "model") if cfg.shard_mode == "zero3" else sharding.BATCH_AXES
        b_spec = _spec_tree(batch, mesh, lambda t: sharding.batch_specs(t, mesh=mesh, axes=b_axes))
        step = steps_mod.make_train_step(cfg, optimizer)
        with mesh:
            jitted = jax.jit(
                step,
                in_shardings=(p_spec, o_spec, b_spec),
                out_shardings=(p_spec, o_spec, None),
            )
            lowered = jitted.lower(p_shapes, o_shapes, batch)
            compiled = lowered.compile()
        mode = f"train/{opt_name}{'+fsdp' if use_fsdp else ''}"
    else:
        scfg = steps_mod.serve_config(cfg)
        p_shapes = steps_mod.param_shapes(scfg)
        p_spec = _spec_tree(p_shapes, mesh, lambda t: sharding.param_specs(t, scfg, fsdp=False, mesh=mesh))
        specs = dict(input_specs(scfg, cell))
        cross = specs.pop("cross_embeds", None)
        cross_spec = None
        if cross is not None:
            cross_spec = _spec_tree(
                {"x": cross}, mesh, lambda t: sharding.batch_specs(t, mesh=mesh)
            )["x"]
        if cell.kind == "prefill":
            step = steps_mod.make_prefill_step(scfg, with_cross=cross is not None)
            tok = specs["tokens"]
            sp = scfg.shard_mode == "dp_sp"
            b_spec = _spec_tree({"tokens": tok}, mesh,
                                lambda t: sharding.batch_specs(t, mesh=mesh, seq_parallel=sp))["tokens"]
            # prefill fills a decode cache sized to the prompt
            c_shapes = steps_mod.cache_shapes(scfg, cell.global_batch, cell.seq_len)
            c_spec = _spec_tree(c_shapes, mesh, lambda t: sharding.cache_specs(t, scfg, mesh=mesh))
            args = [p_shapes, tok, c_shapes]
            in_sh = [p_spec, b_spec, c_spec]
            if cross is not None:
                args.append(cross)
                in_sh.append(cross_spec)
            with mesh:
                jitted = jax.jit(
                    step,
                    in_shardings=tuple(in_sh),
                    out_shardings=(None, c_spec),
                )
                lowered = jitted.lower(*args)
                compiled = lowered.compile()
            mode = "prefill/bf16"
        else:  # decode
            c_shapes = steps_mod.cache_shapes(scfg, cell.global_batch, cell.seq_len)
            c_spec = _spec_tree(c_shapes, mesh, lambda t: sharding.cache_specs(t, scfg, mesh=mesh))
            tok = specs["tokens"]
            pos = specs["position"]
            b_spec = _spec_tree({"tokens": tok}, mesh, lambda t: sharding.batch_specs(t, mesh=mesh))["tokens"]
            step = steps_mod.make_decode_step(scfg, with_cross=cross is not None)
            args = [p_shapes, tok, pos, c_shapes]
            in_sh = [p_spec, b_spec, None, c_spec]
            if cross is not None:
                args.append(cross)
                in_sh.append(cross_spec)
            with mesh:
                jitted = jax.jit(
                    step,
                    in_shardings=tuple(in_sh),
                    out_shardings=(None, c_spec),
                )
                lowered = jitted.lower(*args)
                compiled = lowered.compile()
            mode = "decode/bf16"

    compile_s = time.time() - t0
    cost = compiled.cost_analysis() or {}
    mem = None
    try:
        mem = compiled.memory_analysis()
    except Exception:  # noqa: BLE001 — not implemented on all backends
        mem = None
    hlo = compiled.as_text()
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    report = build_report(
        cell=f"{arch}×{shape_name}×{'2x16x16' if multi_pod else '16x16'}",
        chips=chips,
        flops_per_device=flops_dev,
        hbm_bytes_per_device=bytes_dev,
        hlo_text=hlo,
        model_flops=steps_mod.model_flops_for_cell(cfg, cell),
        bytes_per_device=getattr(mem, "temp_size_in_bytes", None),
    )
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "mode": mode,
        "compile_s": round(compile_s, 1),
        "flops_per_device": flops_dev,
        "raw_bytes_per_device": bytes_dev,
        "fused_bytes_per_device": report.hbm_bytes_global / chips,
        "collective_wire_bytes_per_dev": report.collective_wire_bytes_per_dev,
        "collective_count": report.collective_count,
        "collectives_by_kind": {k: float(v) for k, v in report.by_kind.items()},
        "compute_s": report.compute_s,
        "memory_s": report.memory_s,
        "collective_s": report.collective_s,
        "dominant": report.dominant,
        "model_flops": report.model_flops,
        "useful_flops_ratio": report.useful_flops_ratio,
        "roofline_fraction": report.roofline_fraction,
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                record[attr] = int(v)
    record["residency"] = steps_mod.estimate_residency(
        cfg, cell, chips=chips, fsdp=use_fsdp,
        optimizer=(mode.split("/")[1].split("+")[0] if cell.kind == "train" else "adamw"),
    )
    return record, lowered, compiled


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--optimizer", default="auto")
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--out", default=None, help="directory for per-cell json records")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose record already exists in --out")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=val (bool/int/float), e.g. "
                         "--set opt_attn_layout=true  (§Perf hillclimbs)")
    ap.add_argument("--tag", default="", help="suffix for output record files")
    args = ap.parse_args(argv)

    extra_cfg = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            extra_cfg[k] = v.lower() == "true"
        else:
            try:
                extra_cfg[k] = int(v)
            except ValueError:
                try:
                    extra_cfg[k] = float(v)
                except ValueError:
                    extra_cfg[k] = v

    cells = []
    archs = [args.arch.replace("-", "_")] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    failures = 0
    for arch, shape_name, multi in cells:
        key = (arch, shape_name)
        tag = f"{arch} × {shape_name} × {'multi' if multi else 'single'}"
        if key in SKIPS:
            print(f"SKIP  {tag}: {SKIPS[key]}", flush=True)
            continue
        mesh_tag = "2x16x16" if multi else "16x16"
        if args.skip_existing and args.out and os.path.exists(
            os.path.join(args.out, f"{arch}__{shape_name}__{mesh_tag}.json")
        ):
            print(f"HAVE  {tag}", flush=True)
            continue
        try:
            record, lowered, compiled = lower_cell(
                arch, shape_name, multi_pod=multi,
                optimizer_name=args.optimizer, fsdp=args.fsdp,
                extra_cfg=extra_cfg or None,
            )
            print(
                f"OK    {tag}: compute={record['compute_s']*1e3:.2f}ms "
                f"memory={record['memory_s']*1e3:.2f}ms "
                f"collective={record['collective_s']*1e3:.2f}ms "
                f"dominant={record['dominant']} "
                f"MFU@bound={record['roofline_fraction']:.1%} "
                f"compile={record['compile_s']}s"
            )
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                suffix = f"__{args.tag}" if args.tag else ""
                fname = f"{arch}__{shape_name}__{record['mesh']}{suffix}.json"
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(record, f, indent=1)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"FAIL  {tag}: {type(e).__name__}: {e}")
            traceback.print_exc(limit=4)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
