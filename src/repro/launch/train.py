"""Training driver: data → step → metrics, with checkpoint/restart fault
tolerance, straggler monitoring, and elastic resume.

Runs real steps on whatever devices exist (CPU smoke configs here; the same
driver binds to the production mesh on a pod).  Usage:

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import SHAPES, get_config, get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tfm
from repro.parallel import sharding
from repro.runtime.fault_tolerance import (
    FTConfig,
    FaultInjector,
    RestartPolicy,
    StragglerDetector,
)


@dataclasses.dataclass
class TrainRun:
    """Holds the jitted step and live state; restartable."""

    cfg: object
    step_fn: object
    params: dict
    opt_state: dict
    step: int


def build_run(cfg, mesh, optimizer_name="adamw", seed=0, fsdp=False) -> TrainRun:
    opt_name, optimizer = steps_mod.choose_optimizer(cfg, optimizer_name)
    params = tfm.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = optimizer.init(params)
    p_spec = sharding.to_named(sharding.param_specs(params, cfg, fsdp=fsdp), mesh)
    o_spec = sharding.to_named(sharding.param_specs(opt_state, cfg, fsdp=fsdp), mesh)
    params = jax.device_put(params, p_spec)
    opt_state = jax.device_put(opt_state, o_spec)
    step_fn = jax.jit(
        steps_mod.make_train_step(cfg, optimizer),
        in_shardings=(p_spec, o_spec, None),
        out_shardings=(p_spec, o_spec, None),
        donate_argnums=(0, 1),
    )
    return TrainRun(cfg=cfg, step_fn=step_fn, params=params, opt_state=opt_state, step=0)


def train_loop(
    run: TrainRun,
    stream,
    total_steps: int,
    *,
    ckpt_dir: str | None = None,
    ft: FTConfig | None = None,
    injector: FaultInjector | None = None,
    log_every: int = 10,
    host: str = "host0",
):
    """Fault-tolerant training loop.  Returns (run, history)."""
    ft = ft or FTConfig()
    checkpointer = store.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    detector = StragglerDetector(ft)
    policy = RestartPolicy(max_restarts=ft.max_restarts)
    history = []

    # resume if a checkpoint exists
    if ckpt_dir:
        last = store.latest_step(ckpt_dir)
        if last is not None:
            state = store.restore(
                ckpt_dir, last,
                {"params": run.params, "opt_state": run.opt_state,
                 "step": jnp.zeros((), jnp.int32)},
            )
            run.params, run.opt_state = state["params"], state["opt_state"]
            run.step = int(state["step"])
            print(f"[train] resumed from step {run.step}")

    while run.step < total_steps:
        try:
            t0 = time.time()
            if injector is not None:
                injector.maybe_fail(run.step)
            batch = stream.next_batch(run.step)
            run.params, run.opt_state, metrics = run.step_fn(
                run.params, run.opt_state, batch
            )
            loss = float(metrics["loss"])
            dt = time.time() - t0
            is_straggler = detector.report(host, dt)
            history.append({"step": run.step, "loss": loss, "time_s": dt})
            if run.step % log_every == 0:
                print(f"[train] step={run.step} loss={loss:.4f} {dt*1e3:.0f}ms"
                      + (" STRAGGLER" if is_straggler else ""))
            run.step += 1
            if checkpointer and run.step % ft.checkpoint_every == 0:
                checkpointer.save(
                    {"params": run.params, "opt_state": run.opt_state,
                     "step": jnp.asarray(run.step, jnp.int32)},
                    run.step,
                )
        except (RuntimeError, jax.errors.JaxRuntimeError) as e:
            backoff = policy.on_failure(e)  # raises when budget exhausted
            print(f"[train] failure at step {run.step}: {e}; restart #{policy.restarts} "
                  f"after {backoff:.1f}s backoff")
            time.sleep(min(backoff, 0.1))  # clamped for tests
            if checkpointer:
                checkpointer.wait()
            if ckpt_dir and store.latest_step(ckpt_dir) is not None:
                last = store.latest_step(ckpt_dir)
                state = store.restore(
                    ckpt_dir, last,
                    {"params": run.params, "opt_state": run.opt_state,
                     "step": jnp.zeros((), jnp.int32)},
                )
                run.params, run.opt_state = state["params"], state["opt_state"]
                run.step = int(state["step"])

    if checkpointer:
        if run.step % ft.checkpoint_every:
            checkpointer.save(
                {"params": run.params, "opt_state": run.opt_state,
                 "step": jnp.asarray(run.step, jnp.int32)},
                run.step,
            )
        checkpointer.wait()
    return run, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--inject-fault-at", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    run = build_run(cfg, mesh, optimizer_name=args.optimizer)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(run.params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params on {mesh.devices.size} device(s)")
    stream = SyntheticStream(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.batch)
    )
    injector = FaultInjector({args.inject_fault_at}) if args.inject_fault_at else None
    run, history = train_loop(
        run, stream, args.steps, ckpt_dir=args.ckpt_dir,
        ft=FTConfig(checkpoint_every=10), injector=injector,
    )
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"[train] done: step={run.step} loss {first:.4f} → {last:.4f}")
    return history


if __name__ == "__main__":
    main()
