"""Step-function builders shared by dryrun/train/serve drivers."""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import transformer as tfm
from repro.optim import adafactor, adamw, clip_by_global_norm, warmup_cosine
from repro.optim.adamw import apply_updates


def choose_optimizer(cfg: ModelConfig, name: str = "auto"):
    """Memory plan (DESIGN.md §7): grok-scale models train with Adafactor on
    a single pod; everything else uses AdamW."""
    if name == "auto":
        name = "adafactor" if cfg.param_count() > 1e11 else "adamw"
    if name == "adamw":
        return name, adamw(lr=warmup_cosine(3e-4, 200, 10000), weight_decay=0.1)
    if name == "adamw-fast":
        # smoke/example scale: flat high lr, no decay
        return name, adamw(lr=3e-3, weight_decay=0.0)
    if name == "adafactor":
        return name, adafactor(lr=1e-2)
    raise ValueError(name)


def make_train_step(cfg: ModelConfig, optimizer, *, grad_clip: float = 1.0):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            tfm.loss_fn, has_aux=True
        )(params, batch, cfg)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {**metrics, "loss": loss, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, with_cross: bool = False):
    # cross_embeds is positional: pjit disallows kwargs with in_shardings
    if with_cross:
        def prefill_step(params, tokens, caches, cross_embeds):
            return tfm.prefill(params, tokens, cfg, caches, cross_embeds=cross_embeds)
    else:
        def prefill_step(params, tokens, caches):
            return tfm.prefill(params, tokens, cfg, caches)

    return prefill_step


def make_decode_step(cfg: ModelConfig, with_cross: bool = False):
    if with_cross:
        def decode_step(params, tokens, position, caches, cross_embeds):
            return tfm.decode_step(
                params, tokens, position, cfg, caches, cross_embeds=cross_embeds
            )
    else:
        def decode_step(params, tokens, position, caches):
            return tfm.decode_step(params, tokens, position, cfg, caches)

    return decode_step


def serve_config(cfg: ModelConfig) -> ModelConfig:
    """Serving runs with bf16 weights and no remat."""
    return dataclasses.replace(cfg, param_dtype="bfloat16", remat="none")


def param_shapes(cfg: ModelConfig):
    """ShapeDtypeStruct tree of the parameters — no allocation."""
    return jax.eval_shape(
        functools.partial(tfm.init_params, cfg=cfg), jax.random.PRNGKey(0)
    )


def cache_shapes(cfg: ModelConfig, batch: int, seq_len: int):
    return jax.eval_shape(
        functools.partial(tfm.init_caches, cfg=cfg, batch=batch, seq_len=seq_len)
    )


def opt_state_shapes(optimizer, params_shapes):
    return jax.eval_shape(optimizer.init, params_shapes)


def estimate_residency(
    cfg: ModelConfig,
    cell: ShapeCell,
    *,
    chips: int,
    model_par: int = 16,
    fsdp: bool,
    optimizer: str,
) -> dict:
    """Analytic per-device HBM residency (bytes).  The CPU backend's
    memory_analysis() reflects unfused CPU temps; this is the TPU-side
    bound used for the 'fits in 16 GB' judgement (EXPERIMENTS.md §Dry-run)."""
    n = cfg.param_count()
    pbytes = 4 if cell.kind == "train" else 2
    shard = chips if fsdp else model_par
    params = n * pbytes / shard
    out = {"params": params}
    if cell.kind == "train":
        opt_per_param = {"adamw": 8.0, "adafactor": 4.05}[optimizer]
        out["opt_state"] = n * opt_per_param / shard
        out["grads"] = n * 4 / shard
        tokens_dev = cell.global_batch * cell.seq_len / (chips / model_par)
        # full remat: saved unit inputs + logits/softmax slice
        out["activations"] = tokens_dev * cfg.d_model * 2 * cfg.n_layers / model_par
        out["logits"] = 3 * tokens_dev * cfg.vocab * 2 / model_par
    else:
        kv_layers = sum(1 for k in cfg.layer_kinds() if k in ("global", "moe"))
        loc_layers = sum(1 for k in cfg.layer_kinds() if k == "local")
        batch_dev = max(cell.global_batch / (chips / model_par), 1)
        kvh = max(cfg.n_kv_heads / model_par, 1)
        S = cell.seq_len
        cache = 2 * 2 * batch_dev * kvh * cfg.hd * (
            kv_layers * S + loc_layers * min(S, cfg.window)
        )
        ssm_layers = sum(1 for k in cfg.layer_kinds() if k == "ssm")
        rec_layers = sum(1 for k in cfg.layer_kinds() if k == "rec")
        cache += ssm_layers * batch_dev * (
            4 * max(cfg.n_ssm_heads / model_par, 1) * cfg.ssm_headdim * cfg.ssm_state
        )
        cache += rec_layers * batch_dev * 4 * max(cfg.lru_dim / model_par, 1)
        out["kv_or_state_cache"] = cache
        toks = cell.global_batch * (cell.seq_len if cell.kind == "prefill" else 1)
        out["activations"] = toks / max(chips / model_par, 1) * cfg.d_model * 2 * 4
    out["total"] = sum(out.values())
    out["fits_16gb_hbm"] = out["total"] < 16 * 1024**3
    return out


def model_flops_for_cell(cfg: ModelConfig, cell: ShapeCell) -> float:
    """MODEL_FLOPS per step: 6·N_active·D train, 2·N_active·D inference."""
    n = cfg.active_param_count()
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    return (6.0 if cell.kind == "train" else 2.0) * n * tokens
