"""Production mesh construction (multi-pod dry-run deliverable).

Defined as functions — importing this module never touches jax device
state.  Mesh axes:
  pod   — inter-pod data parallelism (2 pods × 256 chips)
  data  — intra-pod data/FSDP/sequence parallelism (16)
  model — tensor/expert parallelism (16)
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def num_chips(mesh) -> int:
    return mesh.devices.size
