"""Production mesh construction (multi-pod dry-run deliverable).

Defined as functions — importing this module never touches jax device
state.  Mesh axes:
  pod   — inter-pod data parallelism (2 pods × 256 chips)
  data  — intra-pod data/FSDP/sequence parallelism (16)
  model — tensor/expert parallelism (16)
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: no AxisType and no axis_types kwarg
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_abstract_mesh(shape, axes):
    """Version-compatible ``AbstractMesh``: new jax takes ``(sizes, names)``,
    jax <= 0.4.x takes a single ``((name, size), ...)`` tuple."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return _make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def num_chips(mesh) -> int:
    return mesh.devices.size
