"""Trace-and-compile frontend: element-wise PIM programs from plain Python.

Users write functions over typed tracers and get back a compiled multi-op
PIM program (DESIGN.md §3):

    import repro.pim as pim

    mac = pim.compile(lambda a, b, c: a * b + c, dtype=pim.f32)
    out = mac(x, y, z)                      # bit-exact, in-memory
    rep = mac.cost(basis="dram")            # program-level CostReport

Tracing works like ``jax.jit``: the function runs once with :class:`Tracer`
arguments whose arithmetic operators append ops to a :class:`Trace`; the
result is an ``ir.Program`` whose per-op ``aritpim`` netlists are recorded
into **one** ScheduleIR — output values of one op wired directly into the
next, so intermediate planes never round-trip through HBM, and the compiler
passes (fold/cse/fuse/dce/reorder) fire across op boundaries.  Netlists are
picked by the tracer's :class:`~repro.core.bitplanes.PimType` via the
``aritpim.OpSpec`` dtype metadata.  Python scalars mixed into the trace
(``a * b + 2.5``) lower to immediate INIT0/INIT1 constant planes
(``ir.CONST_OP``) — they cost no HBM input traffic and constant folding
sees straight through them.

A single-op trace canonicalizes to ``ir.Program.single``, so e.g.
``pim.compile(lambda a, b: a + b, dtype=pim.f32)`` shares its compile-cache
entry with ``ir.compile_op("float_add")`` and every legacy wrapper.
"""

from __future__ import annotations

import dataclasses
import inspect
import re
from typing import Sequence

import jax.numpy as jnp

from repro.core import aritpim, ir
from repro.core.bitplanes import PimType


class TraceError(TypeError):
    """Raised for untraceable operations (mixed dtypes, non-scalar
    constants, ...)."""


def _encode_scalar(value, dtype: PimType) -> int:
    """A Python scalar's LSB-first bit pattern in ``dtype``'s plane layout.

    Reuses the exact ``PimType`` pack path (cast + ``to_planes`` on a
    one-element array), so constants round/wrap exactly like runtime data:
    floats go through IEEE/bf16 rounding, fixed-point wraps two's-complement
    to ``nbits``.  Non-integral constants are rejected for fixed types."""
    if dtype.kind == "fixed":
        if isinstance(value, float) and not value.is_integer():
            raise TraceError(
                f"constant {value!r} is not representable in {dtype.name}: "
                "fixed-point programs take integral constants only")
        # Wrap to the signed two's-complement representative so the int32
        # carrier accepts it at every width (a raw 32-bit mask of a negative
        # constant would overflow jnp.int32).
        v = int(value) & ((1 << dtype.nbits) - 1)
        if v >= 1 << (dtype.nbits - 1):
            v -= 1 << dtype.nbits
        value = jnp.asarray(v, jnp.int32)
    else:
        # Go through Python float first: an int like 2**35 is exactly what
        # float rounding is for, but would overflow the default int32 path.
        try:
            value = float(value)
        except OverflowError:
            raise TraceError(
                f"constant {value!r} overflows {dtype.name}") from None
    planes = dtype.to_planes(dtype.cast(jnp.asarray(value).reshape(1)))
    return sum((int(p[0]) & 1) << k for k, p in enumerate(planes))


@dataclasses.dataclass(frozen=True)
class Tracer:
    """A typed abstract value flowing through a traced function."""

    trace: "Trace"
    id: int
    dtype: PimType

    def _bin(self, other, arith: str, reverse: bool = False) -> "Tracer":
        if isinstance(other, (int, float, bool)):
            # Scalar constants lower to INIT0/INIT1 immediate planes — they
            # never become HBM inputs (ir.CONST_OP).
            other = self.trace.constant(other, self.dtype)
        if not isinstance(other, Tracer):
            raise TraceError(
                f"cannot apply {arith!r} to a tracer and {type(other).__name__}: "
                "only Python scalars and tracers of the same dtype combine"
            )
        if other.trace is not self.trace:
            raise TraceError("tracers from different traces cannot be combined")
        if other.dtype != self.dtype:
            raise TraceError(
                f"dtype mismatch in {arith!r}: {self.dtype.name} vs "
                f"{other.dtype.name} (no implicit promotion)"
            )
        a, b = (other, self) if reverse else (self, other)
        return self.trace.emit(arith, a, b)

    def __add__(self, other):
        return self._bin(other, "add")

    def __radd__(self, other):
        return self._bin(other, "add", reverse=True)

    def __sub__(self, other):
        return self._bin(other, "sub")

    def __rsub__(self, other):
        return self._bin(other, "sub", reverse=True)

    def __mul__(self, other):
        return self._bin(other, "mul")

    def __rmul__(self, other):
        return self._bin(other, "mul", reverse=True)

    def __truediv__(self, other):
        return self._bin(other, "div")

    def __rtruediv__(self, other):
        return self._bin(other, "div", reverse=True)


class Trace:
    """Accumulates the op graph while the traced function runs."""

    def __init__(self):
        self.in_types: list[PimType] = []
        self.body: list[ir.ProgramOp] = []
        self._next_id = 0
        self._consts: dict[tuple[int, str], Tracer] = {}

    def _fresh(self) -> int:
        v = self._next_id
        self._next_id += 1
        return v

    def input(self, dtype: PimType) -> Tracer:
        assert not self.body, "inputs must be declared before any op"
        self.in_types.append(dtype)
        return Tracer(self, self._fresh(), dtype)

    def constant(self, value, dtype: PimType) -> Tracer:
        """A scalar immediate: one CONST_OP node holding the bit pattern
        (deduplicated per (bits, dtype) so ``a*2 + b*2`` traces one node —
        the dtype is part of the key because two types can share a bit
        pattern, e.g. int16 16256 and bf16 1.0)."""
        bits = _encode_scalar(value, dtype)
        key = (bits, dtype.name)
        hit = self._consts.get(key)
        if hit is not None:
            return hit
        out = self._fresh()
        self.body.append(
            ir.ProgramOp(ir.CONST_OP, (), out, dtype.width, imm=bits))
        tracer = Tracer(self, out, dtype)
        self._consts[key] = tracer
        return tracer

    def emit(self, arith: str, a: Tracer, b: Tracer) -> Tracer:
        op = aritpim.op_for(arith, a.dtype.kind)
        out = self._fresh()
        # Keep dtype.width planes of the result: fixed-point multiplies wrap
        # (low half of the 2n-bit product; DCE deletes the dead high half).
        self.body.append(ir.ProgramOp(op, (a.id, b.id), out, a.dtype.width))
        return Tracer(self, out, a.dtype)


def _canonical_program(trace: Trace, outputs: Sequence[Tracer], name: str) -> ir.Program:
    """Build the ir.Program; single-op full-width traces canonicalize to
    ``Program.single`` so they share cache entries with ``compile_op``."""
    if len(trace.body) == 1 and len(outputs) == 1:
        node = trace.body[0]
        spec = aritpim._OP_TABLE[node.op]
        nbits = trace.in_types[0].nbits
        if (
            node.args == (0, 1)
            and outputs[0].id == node.out
            and len(trace.in_types) == 2
            and tuple(t.width for t in trace.in_types) == spec.in_widths(nbits)
            and node.width == spec.out_width(nbits)
        ):
            return ir.Program.single(node.op, nbits)
    return ir.Program(
        in_widths=tuple(t.width for t in trace.in_types),
        body=tuple(trace.body),
        outputs=tuple(t.id for t in outputs),
        name=name,
    )


@dataclasses.dataclass(frozen=True)
class CompiledPimFunction:
    """The compile() artifact: callable + program-level cost reporting.

    Execution and analytics are lazy and cached per ``(basis, passes)`` via
    the ``ir`` compile cache, so constructing one (e.g. at module import in
    ``kernels.ops``) costs only the trace."""

    program: ir.Program
    in_types: tuple[PimType, ...]
    out_types: tuple[PimType, ...]
    backend: str = "pallas"

    def compiled(self, basis: str = "memristive",
                 passes: tuple[str, ...] = ir.DEFAULT_PASSES) -> ir.CompiledSchedule:
        return ir.compile_program(self.program, passes, basis)

    def cost(self, basis: str = "memristive",
             passes: tuple[str, ...] = ir.DEFAULT_PASSES) -> ir.CostReport:
        """Program-level CostReport from the analytical backend."""
        return ir.program_cost(self.program, passes, basis)

    def __call__(self, *arrays, basis: str = "memristive",
                 passes: tuple[str, ...] = ir.DEFAULT_PASSES,
                 backend: str | None = None, interpret: bool = True,
                 mode: str | None = None):
        if len(arrays) != len(self.in_types):
            raise TypeError(
                f"expected {len(self.in_types)} arrays, got {len(arrays)}")
        arrays = [t.cast(x) for t, x in zip(self.in_types, arrays)]
        n = arrays[0].shape[0]
        planes = jnp.stack(
            [p for t, x in zip(self.in_types, arrays) for p in t.to_planes(x)]
        )
        compiled = self.compiled(basis, passes)
        name = backend or self.backend
        if mode is not None and not name.startswith("pallas"):
            raise ValueError(
                f"executor mode {mode!r} only applies to the pallas "
                f"backends, not {name!r}")
        opts = {} if mode is None else {"mode": mode}
        out = ir.get_backend(name).run(
            compiled, planes, interpret=interpret, **opts).planes
        results, i = [], 0
        for t in self.out_types:
            results.append(t.from_planes([out[i + j] for j in range(t.width)], n))
            i += t.width
        return results[0] if len(results) == 1 else tuple(results)


def trace(fn, dtype) -> CompiledPimFunction:
    """Trace ``fn`` into a Program without committing to a backend."""
    try:
        params = list(inspect.signature(fn).parameters.values())
    except (TypeError, ValueError):  # builtins / C callables
        raise TraceError("cannot inspect the traced function's signature")
    if any(p.kind not in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
           for p in params):
        raise TraceError(
            "traced functions must take plain positional arguments "
            "(*args/**kwargs/keyword-only parameters are not traceable)")
    n_args = len(params)
    if isinstance(dtype, PimType):
        dtypes = (dtype,) * n_args
    else:
        dtypes = tuple(dtype)
        if len(dtypes) != n_args:
            raise TraceError(
                f"{len(dtypes)} dtypes for a {n_args}-argument function")
    t = Trace()
    args = [t.input(d) for d in dtypes]
    result = fn(*args)
    outs = result if isinstance(result, (tuple, list)) else (result,)
    if not outs or not all(isinstance(o, Tracer) and o.trace is t for o in outs):
        raise TraceError("the traced function must return its tracer value(s)")
    name = re.sub(r"[^A-Za-z0-9_]", "", getattr(fn, "__name__", "")) or "program"
    program = _canonical_program(t, outs, name)
    return CompiledPimFunction(
        program=program,
        in_types=dtypes,
        out_types=tuple(o.dtype for o in outs),
    )


def compile(fn, dtype, backend: str = "pallas") -> CompiledPimFunction:  # noqa: A001
    """Trace-and-compile an element-wise PIM program (the public API).

    ``dtype`` is one :class:`PimType` for all arguments or a sequence of
    per-argument types (both operands of every op must agree — there is no
    implicit promotion).  The returned function packs arrays to bit-planes,
    executes the fused program on the requested executor backend
    (``pallas`` by default, ``interpret=True`` on CPU) and unpacks the
    result; ``.cost(basis=...)`` prices it analytically on either basis.
    """
    return dataclasses.replace(trace(fn, dtype), backend=backend)
