"""``repro.pim`` — the public trace-and-compile PIM frontend.

    import repro.pim as pim

    mac = pim.compile(lambda a, b, c: a * b + c, dtype=pim.f32)
    z = mac(x, y, c)                       # fused in-memory execution
    rep = mac.cost(basis="dram")           # program-level CostReport

Types: ``pim.f32``, ``pim.bf16``, ``pim.fixed(n)`` (with ``int8``/``int16``/
``int32`` aliases).  See DESIGN.md §3–4 and the README quickstart.
"""

from repro.core.bitplanes import BF16 as bf16
from repro.core.bitplanes import F32 as f32
from repro.core.bitplanes import PimType, fixed

from .frontend import CompiledPimFunction, TraceError, Tracer, compile, trace

int8 = fixed(8)
int16 = fixed(16)
int32 = fixed(32)

__all__ = [
    "compile", "trace", "CompiledPimFunction", "Tracer", "TraceError",
    "PimType", "f32", "bf16", "fixed", "int8", "int16", "int32",
]
