"""repro.checkpoint"""
