"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json      — step, flat key list, shapes/dtypes, status
            <flat-key>.npy     — one array per leaf (host-local full arrays)

Commit protocol: arrays are written into ``step_<N>.tmp`` and the directory
is atomically renamed after the manifest is fsync'd — a crash mid-save never
corrupts the latest complete checkpoint.  ``save_async`` runs the device→host
copy synchronously (cheap) and the file I/O on a worker thread, off the
training critical path.

Elastic restore: leaves are stored unsharded, so ``restore`` can device_put
onto ANY mesh/sharding (different pod count, data/model split) — the
re-layout plan is just the new NamedShardings (runtime/elastic.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

_SEP = "::"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def save(tree, directory: str, step: int) -> str:
    """Synchronous atomic checkpoint.  Returns the committed path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "keys": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "_") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["keys"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc_old(directory, keep=3)
    return final


class AsyncCheckpointer:
    """Device→host copy on the caller thread; file I/O on a worker."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: threading.Thread | None = None

    def save(self, tree, step: int):
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save, args=(host_tree, self.directory, step), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for elastic placement onto the current mesh."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_like = _flatten(like)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, meta in manifest["keys"].items():
        if key not in flat_like:
            continue  # allows restoring a sub-tree (e.g. params only)
        arr = np.load(os.path.join(path, meta["file"]))
        if key in flat_shard:
            out[key] = jax.device_put(arr, flat_shard[key])
        else:
            out[key] = jax.numpy.asarray(arr)
    # missing keys (new tree entries after an upgrade) keep `like` values
    for key, leaf in flat_like.items():
        out.setdefault(key, leaf)
    return _unflatten_like(like, out)


def _unflatten_like(like, flat: dict):
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), leaves)


def _gc_old(directory: str, keep: int):
    steps = sorted(
        n for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp")
    )
    for name in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
