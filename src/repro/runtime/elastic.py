"""Elastic re-scaling: restore a checkpoint onto a different mesh.

Checkpoints store full (unsharded) arrays (checkpoint/store.py), so scaling
from e.g. a 2-pod (2,16,16) mesh down to one pod (16,16) — or up — is a
restore with the *new* mesh's NamedShardings.  The data stream is stateless
in (seed, step) (data/pipeline.py), so the token stream continues exactly.
What changes on re-scale is captured in a RescalePlan for the operator log.
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh

from repro.checkpoint import store
from repro.configs.base import ModelConfig
from repro.parallel import sharding


@dataclasses.dataclass
class RescalePlan:
    old_shape: tuple
    new_shape: tuple
    per_device_batch_old: float
    per_device_batch_new: float
    notes: list[str]


def plan_rescale(old_mesh_shape: dict, new_mesh_shape: dict, global_batch: int) -> RescalePlan:
    old_n = 1
    for v in old_mesh_shape.values():
        old_n *= v
    new_n = 1
    for v in new_mesh_shape.values():
        new_n *= v
    notes = []
    old_dp = old_mesh_shape.get("pod", 1) * old_mesh_shape.get("data", 1)
    new_dp = new_mesh_shape.get("pod", 1) * new_mesh_shape.get("data", 1)
    if global_batch % new_dp:
        notes.append(
            f"global_batch {global_batch} not divisible by new DP degree {new_dp}: "
            "GSPMD pads the batch dim"
        )
    if new_n < old_n:
        notes.append("scale-down: verify per-device memory with dryrun before resuming")
    return RescalePlan(
        old_shape=tuple(old_mesh_shape.items()),
        new_shape=tuple(new_mesh_shape.items()),
        per_device_batch_old=global_batch / old_dp,
        per_device_batch_new=global_batch / new_dp,
        notes=notes,
    )


def restore_onto_mesh(
    directory: str,
    step: int,
    like_tree,
    mesh: Mesh,
    cfg: ModelConfig,
    fsdp: bool = False,
):
    """Restore a checkpoint onto ``mesh`` regardless of the mesh it was
    saved from."""
    specs = sharding.param_specs(like_tree, cfg, fsdp=fsdp)
    named = sharding.to_named(specs, mesh)
    return store.restore(directory, step, like_tree, shardings=named)
