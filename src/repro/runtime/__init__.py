"""repro.runtime"""
