"""Fault tolerance: failure detection, straggler mitigation, restart policy.

On a real multi-pod fleet the signals come from the coordination service
(missed heartbeats, slow all-reduce participants); here the monitor consumes
per-host step-duration reports — injected by tests/examples — and the driver
(launch/train.py) wires detection → checkpoint-restore → continue.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque


@dataclasses.dataclass
class FTConfig:
    checkpoint_every: int = 50
    max_restarts: int = 3
    heartbeat_timeout_s: float = 60.0
    straggler_factor: float = 2.5  # step > factor × rolling median ⇒ straggler
    straggler_window: int = 32
    straggler_patience: int = 3  # consecutive flags before action


class HeartbeatMonitor:
    """Tracks per-host liveness from heartbeat timestamps."""

    def __init__(self, hosts: list[str], timeout_s: float):
        self.timeout_s = timeout_s
        self.last_seen = {h: time.monotonic() for h in hosts}

    def beat(self, host: str, now: float | None = None):
        self.last_seen[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: float | None = None) -> list[str]:
        t = time.monotonic() if now is None else now
        return [h for h, seen in self.last_seen.items() if t - seen > self.timeout_s]


class StragglerDetector:
    """Rolling-median outlier filter over per-host step durations.

    A host whose step time exceeds ``factor × median`` for ``patience``
    consecutive steps is flagged for mitigation (preemptive restart /
    traffic re-route — the driver decides)."""

    def __init__(self, cfg: FTConfig):
        self.cfg = cfg
        self.history: dict[str, deque] = {}
        self.flags: dict[str, int] = {}

    def report(self, host: str, step_time_s: float) -> bool:
        """Record a measurement.  Returns True if host is now a confirmed
        straggler."""
        h = self.history.setdefault(host, deque(maxlen=self.cfg.straggler_window))
        h.append(step_time_s)
        med = self._global_median()
        if med > 0 and step_time_s > self.cfg.straggler_factor * med:
            self.flags[host] = self.flags.get(host, 0) + 1
        else:
            self.flags[host] = 0
        return self.flags.get(host, 0) >= self.cfg.straggler_patience

    def _global_median(self) -> float:
        all_t = sorted(t for h in self.history.values() for t in h)
        if not all_t:
            return 0.0
        return all_t[len(all_t) // 2]


@dataclasses.dataclass
class RestartPolicy:
    """Bounded-restart supervision with exponential backoff."""

    max_restarts: int = 3
    backoff_s: float = 1.0
    restarts: int = 0

    def on_failure(self, exc: BaseException) -> float:
        """Returns backoff seconds before retry; raises if budget exhausted."""
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError(
                f"restart budget exhausted after {self.restarts - 1} restarts"
            ) from exc
        return self.backoff_s * (2 ** (self.restarts - 1))


class FaultInjector:
    """Deterministic fault injection for tests/examples: raises at the
    configured steps (simulating preemption / device loss)."""

    def __init__(self, fail_at_steps: set[int]):
        self.fail_at = set(fail_at_steps)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")
