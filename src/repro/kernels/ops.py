"""Jit'd public wrappers around the Pallas kernels.

``pim_float_add/pim_float_mul/pim_fixed_add`` run the recorded NOR schedule
through the ``pim_bitserial`` kernel (interpret mode on CPU; compiled on a
real TPU) and convert packed bit-planes back to ordinary arrays.
``pim_matmul`` is the MatPIM-schedule blocked matmul.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

from repro.core import aritpim, bitplanes

from . import pim_bitserial, pim_matmul


@functools.lru_cache(maxsize=None)
def _ensure(key: str, nbits: int = 32):
    sched = aritpim.build_schedule(key, nbits=nbits, compress=True)
    reg_key = f"{key}{nbits}"
    pim_bitserial.register_schedule(reg_key, sched)
    return reg_key, sched


def _binary_f32(opname: str, x, y, interpret: bool = True):
    key, sched = _ensure(opname)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    n = x.shape[0]
    planes = jnp.stack(bitplanes.f32_to_planes(x) + bitplanes.f32_to_planes(y))
    out = pim_bitserial.run_schedule(key, planes, interpret=interpret)
    return bitplanes.planes_to_f32([out[i] for i in range(32)], n)


def pim_float_add(x, y, interpret: bool = True):
    return _binary_f32("float_add", x, y, interpret)


def pim_float_mul(x, y, interpret: bool = True):
    return _binary_f32("float_mul", x, y, interpret)


def pim_fixed_add(x, y, nbits: int = 32, interpret: bool = True):
    key, sched = _ensure("fixed_add", nbits)
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    n = x.shape[0]
    planes = jnp.stack(
        bitplanes.int_to_planes(x, nbits) + bitplanes.int_to_planes(y, nbits)
    )
    out = pim_bitserial.run_schedule(key, planes, interpret=interpret)
    return bitplanes.planes_to_int([out[i] for i in range(nbits)], n, signed=True)


def pim_matmul_op(a, b, *, bm=128, bk=128, bn=128, interpret: bool = True):
    return pim_matmul.matmul(a, b, bm=bm, bk=bk, bn=bn, interpret=interpret)


def schedule_info(opname: str, nbits: int = 32):
    """(gates, compressed columns) for an op — used by benchmarks/tests."""
    _, sched = _ensure(opname, nbits)
    return sched.num_gates, sched.num_cols
