"""Jit'd public wrappers around the Pallas kernels.

``pim_float_add/pim_float_mul/pim_bf16_add/pim_bf16_mul/pim_fixed_add`` run
schedules compiled by the ``repro.core.ir`` pipeline (record → optimization
passes → liveness column allocation) through the ``pallas`` executor backend
(interpret mode on CPU; compiled on a real TPU) and convert packed bit-planes
back to ordinary arrays.  ``pim_matmul`` is the MatPIM-schedule blocked
matmul.  Everything pulls from the one compile cache keyed by
``(op, nbits, basis, pass_list)`` — adding an op here is a registration, not
a new code path, and every wrapper takes ``basis="memristive"|"dram"`` to
execute the NOR or the MAJ3/NOT lowering of the same netlist.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitplanes, ir

from . import pim_matmul


def _run_planes(op: str, nbits: int, planes: jnp.ndarray, interpret: bool,
                basis: str = "memristive") -> jnp.ndarray:
    compiled = ir.compile_op(op, nbits=nbits, basis=basis)  # memoized in ir's cache
    return ir.get_backend("pallas").run(compiled, planes, interpret=interpret).planes


def _binary_f32(opname: str, x, y, interpret: bool = True, basis: str = "memristive"):
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    n = x.shape[0]
    planes = jnp.stack(bitplanes.f32_to_planes(x) + bitplanes.f32_to_planes(y))
    out = _run_planes(opname, 32, planes, interpret, basis)
    return bitplanes.planes_to_f32([out[i] for i in range(32)], n)


def pim_float_add(x, y, interpret: bool = True, basis: str = "memristive"):
    return _binary_f32("float_add", x, y, interpret, basis)


def pim_float_mul(x, y, interpret: bool = True, basis: str = "memristive"):
    return _binary_f32("float_mul", x, y, interpret, basis)


def _binary_bf16(opname: str, x, y, interpret: bool = True, basis: str = "memristive"):
    x = jnp.asarray(x, jnp.bfloat16)
    y = jnp.asarray(y, jnp.bfloat16)
    n = x.shape[0]
    planes = jnp.stack(bitplanes.bf16_to_planes(x) + bitplanes.bf16_to_planes(y))
    out = _run_planes(opname, 16, planes, interpret, basis)
    return bitplanes.planes_to_bf16([out[i] for i in range(16)], n)


def pim_bf16_add(x, y, interpret: bool = True, basis: str = "memristive"):
    return _binary_bf16("bf16_add", x, y, interpret, basis)


def pim_bf16_mul(x, y, interpret: bool = True, basis: str = "memristive"):
    return _binary_bf16("bf16_mul", x, y, interpret, basis)


def pim_fixed_add(x, y, nbits: int = 32, interpret: bool = True,
                  basis: str = "memristive"):
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    n = x.shape[0]
    planes = jnp.stack(
        bitplanes.int_to_planes(x, nbits) + bitplanes.int_to_planes(y, nbits)
    )
    out = _run_planes("fixed_add", nbits, planes, interpret, basis)
    return bitplanes.planes_to_int([out[i] for i in range(nbits)], n, signed=True)


def pim_fixed_mul(x, y, nbits: int = 32, interpret: bool = True,
                  basis: str = "memristive"):
    """Signed N×N multiply; returns the low N bits (wrapping, like int mul)."""
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    n = x.shape[0]
    planes = jnp.stack(
        bitplanes.int_to_planes(x, nbits) + bitplanes.int_to_planes(y, nbits)
    )
    out = _run_planes("fixed_mul", nbits, planes, interpret, basis)
    return bitplanes.planes_to_int([out[i] for i in range(nbits)], n, signed=True)


def pim_matmul_op(a, b, *, bm=128, bk=128, bn=128, interpret: bool = True):
    return pim_matmul.matmul(a, b, bm=bm, bk=bk, bn=bn, interpret=interpret)


def schedule_info(opname: str, nbits: int = 32, basis: str = "memristive"):
    """(recorded schedule length, allocated columns) — benchmarks/tests."""
    compiled = ir.compile_op(opname, nbits=nbits, basis=basis)
    return compiled.recorded_len, compiled.num_cols
