"""Jit'd public wrappers — thin aliases over the ``repro.pim`` frontend.

Every ``pim_*`` arithmetic wrapper is now a one-line alias over a traced
``repro.pim`` program: the frontend packs planes via the
``bitplanes.PimType`` layouts, compiles through the one ``repro.core.ir``
cache (single-op traces canonicalize to the same cache entries as
``ir.compile_op``) and executes on the ``pallas`` backend (interpret mode on
CPU; compiled on a real TPU).  Adding a wrapper is a registration, not a new
code path, and every wrapper takes ``basis="memristive"|"dram"`` to execute
the NOR or the MAJ3/NOT lowering of the same netlist, plus
``mode="auto"|"unrolled"|"loop"`` to pick the executor kernel (wave-scheduled
straight-line vs fori_loop; auto selects by gate count — DESIGN.md §5).
``pim_matmul`` is the MatPIM-schedule blocked matmul.
"""

from __future__ import annotations

import functools

import repro.pim as pim

from . import pim_matmul

_ARITH_FNS = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
}


@functools.lru_cache(maxsize=None)
def _fn(arith: str, dtype_name: str, nbits: int) -> pim.CompiledPimFunction:
    dtype = {"f32": pim.f32, "bf16": pim.bf16}.get(dtype_name) or pim.fixed(nbits)
    return pim.compile(_ARITH_FNS[arith], dtype=dtype, backend="pallas")


def pim_float_add(x, y, interpret: bool = True, basis: str = "memristive",
                  mode: str | None = None):
    return _fn("add", "f32", 32)(x, y, interpret=interpret, basis=basis, mode=mode)


def pim_float_sub(x, y, interpret: bool = True, basis: str = "memristive",
                  mode: str | None = None):
    return _fn("sub", "f32", 32)(x, y, interpret=interpret, basis=basis, mode=mode)


def pim_float_mul(x, y, interpret: bool = True, basis: str = "memristive",
                  mode: str | None = None):
    return _fn("mul", "f32", 32)(x, y, interpret=interpret, basis=basis, mode=mode)


def pim_float_div(x, y, interpret: bool = True, basis: str = "memristive",
                  mode: str | None = None):
    return _fn("div", "f32", 32)(x, y, interpret=interpret, basis=basis, mode=mode)


def pim_bf16_add(x, y, interpret: bool = True, basis: str = "memristive",
                  mode: str | None = None):
    return _fn("add", "bf16", 16)(x, y, interpret=interpret, basis=basis, mode=mode)


def pim_bf16_sub(x, y, interpret: bool = True, basis: str = "memristive",
                  mode: str | None = None):
    return _fn("sub", "bf16", 16)(x, y, interpret=interpret, basis=basis, mode=mode)


def pim_bf16_mul(x, y, interpret: bool = True, basis: str = "memristive",
                  mode: str | None = None):
    return _fn("mul", "bf16", 16)(x, y, interpret=interpret, basis=basis, mode=mode)


def pim_fixed_add(x, y, nbits: int = 32, interpret: bool = True,
                  basis: str = "memristive", mode: str | None = None):
    return _fn("add", "fixed", nbits)(x, y, interpret=interpret, basis=basis, mode=mode)


def pim_fixed_sub(x, y, nbits: int = 32, interpret: bool = True,
                  basis: str = "memristive", mode: str | None = None):
    return _fn("sub", "fixed", nbits)(x, y, interpret=interpret, basis=basis, mode=mode)


def pim_fixed_mul(x, y, nbits: int = 32, interpret: bool = True,
                  basis: str = "memristive", mode: str | None = None):
    """Signed N×N multiply; returns the low N bits (wrapping, like int mul)."""
    return _fn("mul", "fixed", nbits)(x, y, interpret=interpret, basis=basis, mode=mode)


def pim_fixed_div(x, y, nbits: int = 32, interpret: bool = True,
                  basis: str = "memristive", mode: str | None = None):
    """Signed division (C truncation semantics); x//0 is the netlist's
    documented all-ones convention."""
    return _fn("div", "fixed", nbits)(x, y, interpret=interpret, basis=basis, mode=mode)


def pim_matmul_op(a, b, *, bm=128, bk=128, bn=128, interpret: bool = True):
    return pim_matmul.matmul(a, b, bm=bm, bk=bk, bn=bn, interpret=interpret)


def schedule_info(opname: str, nbits: int = 32, basis: str = "memristive"):
    """(recorded schedule length, allocated columns) — benchmarks/tests."""
    from repro.core import ir

    compiled = ir.compile_op(opname, nbits=nbits, basis=basis)
    return compiled.recorded_len, compiled.num_cols
