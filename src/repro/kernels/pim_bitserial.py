"""Pallas TPU kernel: bit-serial element-parallel PIM gate-schedule executor.

TPU-native adaptation of the paper's crossbar column ops (DESIGN.md §2): a
crossbar column over R rows becomes a lane-packed ``uint32`` bit-plane of
``R/32`` words; the serial gate schedule becomes a sequence of bitwise VPU
ops over VMEM-resident planes.  The ``fori_loop`` dispatch executes both
logic bases — memristive NOR rows and the DRAM basis' MAJ3/NOT rows — so one
kernel serves every ``(program, basis, passes)`` compile, including fused
multi-op programs from the ``repro.pim`` frontend: the static input/output
slot maps carry however many named operands/results the program declares.
HBM traffic is exactly the program's boundary planes (inputs read + outputs
written; ``CostReport.hbm_planes``) — independent of schedule length, and
intermediate values of a fused program never leave VMEM, exactly the
in-memory property the paper models.

The kernel is the ``pallas`` executor backend of the compiler pipeline
(DESIGN.md §3–4): it consumes an optimized ``ir.CompiledSchedule`` whose
static input/output slot maps are baked into the kernel closure, and
registers itself in ``ir``'s backend registry on import.

Tiling: the grid runs over blocks of the packed-words axis; each program
holds the *entire* (column-allocated) crossbar state for its word-block in a
VMEM scratch of shape ``[num_cols, BLOCK_WORDS]``.  The allocated column
count (≤133 for float32 ops, see ``ir.lower``) and ``BLOCK_WORDS=256`` give
a ~136 KiB working set — comfortably inside VMEM and an exact analogue of
one crossbar's 1024-column budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ir
from repro.core.machine import (
    OP_INIT0,
    OP_INIT1,
    OP_MAJ3,
    OP_NOR,
    OP_NOT,
    Schedule,
)

BLOCK_WORDS = 256
UMAX32 = 0xFFFFFFFF  # python int: folded into the kernel, not a captured array


def _kernel(op_ref, a_ref, b_ref, c_ref, o_ref, in_ref, out_ref, state, *,
            input_slots, output_slots):
    # Load this block's input planes into their crossbar columns (static slots).
    for i, col in enumerate(input_slots):
        state[col, :] = in_ref[i, :]

    n_gates = op_ref.shape[0]

    def body(g, _):
        op = op_ref[g]
        a = a_ref[g]
        b = b_ref[g]
        c = c_ref[g]
        o = o_ref[g]
        va = pl.load(state, (pl.dslice(a, 1), slice(None)))
        vb = pl.load(state, (pl.dslice(b, 1), slice(None)))
        vc = pl.load(state, (pl.dslice(c, 1), slice(None)))
        nor = ~(va | vb)
        maj = (va & vb) | (va & vc) | (vb & vc)
        res = jnp.where(
            op == OP_NOR, nor,
            jnp.where(op == OP_MAJ3, maj,
                      jnp.where(op == OP_NOT, ~va,
                                jnp.where(op == OP_INIT0, jnp.zeros_like(nor),
                                          jnp.where(op == OP_INIT1,
                                                    jnp.full_like(nor, UMAX32),
                                                    va)))),
        )
        pl.store(state, (pl.dslice(o, 1), slice(None)), res)
        return 0

    jax.lax.fori_loop(0, n_gates, body, 0)

    for i, col in enumerate(output_slots):
        out_ref[i, :] = state[col, :]


@functools.partial(jax.jit, static_argnames=("schedule_key", "interpret"))
def _run(op, a, b, c, o, planes, *, schedule_key, interpret):
    compiled = _SCHEDULES[schedule_key]
    input_slots = compiled.input_slots
    output_slots = compiled.output_slots
    n_in, W = planes.shape
    n_out = len(output_slots)
    grid = (W // BLOCK_WORDS,)
    return pl.pallas_call(
        functools.partial(_kernel, input_slots=tuple(input_slots), output_slots=tuple(output_slots)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((op.shape[0],), lambda i: (0,)),
            pl.BlockSpec((a.shape[0],), lambda i: (0,)),
            pl.BlockSpec((b.shape[0],), lambda i: (0,)),
            pl.BlockSpec((c.shape[0],), lambda i: (0,)),
            pl.BlockSpec((o.shape[0],), lambda i: (0,)),
            pl.BlockSpec((n_in, BLOCK_WORDS), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n_out, BLOCK_WORDS), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n_out, W), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((compiled.num_cols, BLOCK_WORDS), jnp.uint32)],
        interpret=interpret,
    )(op, a, b, c, o, planes)


# Registry of compiled schedules (keyed so jit can treat them as static).
_SCHEDULES: dict[str, ir.CompiledSchedule] = {}


def register_compiled(compiled: ir.CompiledSchedule, key: str | None = None) -> str:
    key = key or compiled.key
    _SCHEDULES[key] = compiled
    return key


def register_schedule(key: str, schedule: Schedule | ir.CompiledSchedule) -> None:
    """Register a schedule under ``key``.  Accepts a ``CompiledSchedule`` or a
    legacy (column-allocated) ``machine.Schedule``, which is wrapped as-is."""
    if isinstance(schedule, ir.CompiledSchedule):
        _SCHEDULES[key] = schedule
        return
    _SCHEDULES[key] = ir.CompiledSchedule.from_legacy(schedule, key=key)


def run_schedule(key: str, planes: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Execute registered schedule ``key`` over stacked input planes.

    planes: ``[n_inputs, W]`` uint32 — inputs concatenated in sorted-name
    order (matching ``CompiledSchedule.input_slots``).  Returns
    ``[n_outputs, W]``.  W is padded to a BLOCK_WORDS multiple internally.
    """
    compiled = _SCHEDULES[key]
    assert planes.shape[0] == len(compiled.input_slots), (
        planes.shape, len(compiled.input_slots))
    W = planes.shape[1]
    pad = (-W) % BLOCK_WORDS
    if pad:
        planes = jnp.pad(planes, ((0, 0), (0, pad)))
    op, a, b, c, o = compiled.as_arrays()
    out = _run(op, a, b, c, o, planes, schedule_key=key, interpret=interpret)
    return out[:, :W]


class PallasBackend(ir.Backend):
    """TPU executor: one VMEM-resident crossbar per word-block (interpret
    mode executes the same kernel body on CPU)."""

    name = "pallas"

    def run(self, compiled, planes=None, interpret: bool = True, **opts):
        assert planes is not None, "pallas backend needs input planes"
        key = register_compiled(compiled)
        out = run_schedule(key, planes, interpret=interpret)
        return ir.ExecutionResult(out, self.cost(compiled))


ir.register_backend(PallasBackend())
