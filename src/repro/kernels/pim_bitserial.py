"""Pallas TPU kernel: bit-serial element-parallel PIM gate-schedule executor.

TPU-native adaptation of the paper's crossbar column ops (DESIGN.md §2): a
crossbar column over R rows becomes a lane-packed ``uint32`` bit-plane of
``R/32`` words; the serial gate schedule becomes a sequence of bitwise VPU
ops over VMEM-resident planes.  Both logic bases execute here — memristive
NOR rows and the DRAM basis' MAJ3/NOT rows — so one executor serves every
``(program, basis, passes)`` compile, including fused multi-op programs from
the ``repro.pim`` frontend.  HBM traffic is exactly the program's boundary
planes (``CostReport.hbm_planes``) — independent of schedule length, the
in-memory property the paper models.

Two executor modes share the registry (DESIGN.md §5):

* ``loop`` — the original ``fori_loop`` kernel: one gate per iteration,
  dynamic single-row ``pl.load``/``pl.store`` plus a five-deep ``jnp.where``
  opcode select, and the five gate arrays shipped to the device.  O(1)
  compile in schedule length, but each gate pays dynamic-indexing and
  select overhead — orders of magnitude slower than the bitwise VPU ops it
  dispatches.
* ``unrolled`` — a **wave-scheduled straight-line** kernel generated from
  the fact that ``(op, a, b, c, o)`` are static per ``CompiledSchedule``:
  the body is Python-unrolled bitwise ops on fixed ``state[col]`` indices —
  no dynamic indexing, no opcode-select chain, no scalar gate arrays on the
  device.  Gates are grouped into hazard-free *wave chunks* (no gate reads
  a column written earlier in its chunk), emitted read-then-write so every
  chunk is a batch of mutually independent VPU ops; long schedules are
  split into segments of ``UNROLL_SEGMENT_GATES`` at chunk boundaries
  (XLA compile time is superlinear in straight-line length) with the
  column state threaded between segment kernels.  In ``interpret`` mode the
  identical generated body runs as a plain jit — skipping the
  ``pallas_call`` emulation layer, which only adds tracing overhead on CPU;
  on hardware each segment is a ``pl.pallas_call`` with the grid over
  word-blocks and the state block aliased in/out.

The ``pallas`` backend picks the mode automatically by gate count
(``UNROLL_AUTO_MAX_GATES``): short schedules unroll, very long ones fall
back to the loop kernel.  ``pallas-unrolled`` / ``pallas-loop`` force one
mode (the CI perf gate in ``benchmarks/smoke.py`` races them on the f32
fused MAC).  Per-schedule artifacts — the gate arrays and their device
upload for the loop kernel, the wave-chunked segments for the unrolled
kernel — are cached by schedule key, so repeat dispatches stop rebuilding
and re-transferring them.

Tiling: the grid runs over blocks of the packed-words axis; each program
holds the *entire* (column-allocated) crossbar state for its word-block in
``[num_cols, BLOCK_WORDS]`` — with ``num_cols ≤ 133`` for float ops (see
``ir.lower`` and the ``reorder`` pass) and ``BLOCK_WORDS = 256`` that is a
~136 KiB working set, comfortably inside VMEM and an exact analogue of one
crossbar's 1024-column budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ir
from repro.core.machine import (
    OP_INIT0,
    OP_INIT1,
    OP_MAJ3,
    OP_NOR,
    OP_NOT,
    Schedule,
    operand_slots,
)

BLOCK_WORDS = 256
UMAX32 = 0xFFFFFFFF  # python int: folded into the kernel, not a captured array

# Mode-auto threshold: schedules at or below this many gates unroll; longer
# ones keep the fori_loop kernel (straight-line XLA compile time grows
# superlinearly, so unrolling a 20k-gate divider buys compile pain for a
# win the loop kernel amortizes anyway).  Force a mode with the
# ``pallas-unrolled`` / ``pallas-loop`` backends.
UNROLL_AUTO_MAX_GATES = 1024
# Straight-line gates per generated segment kernel; boundaries snap to wave
# chunk edges.  ~4 s of XLA-CPU compile per segment, amortized by the
# per-key segment cache.
UNROLL_SEGMENT_GATES = 1024


# ---------------------------------------------------------------------------
# fori_loop kernel (the `loop` mode)
# ---------------------------------------------------------------------------


def _kernel(op_ref, a_ref, b_ref, c_ref, o_ref, in_ref, out_ref, state, *,
            input_slots, output_slots):
    # Load this block's input planes into their crossbar columns (static slots).
    for i, col in enumerate(input_slots):
        state[col, :] = in_ref[i, :]

    n_gates = op_ref.shape[0]

    def body(g, _):
        op = op_ref[g]
        a = a_ref[g]
        b = b_ref[g]
        c = c_ref[g]
        o = o_ref[g]
        va = pl.load(state, (pl.dslice(a, 1), slice(None)))
        vb = pl.load(state, (pl.dslice(b, 1), slice(None)))
        vc = pl.load(state, (pl.dslice(c, 1), slice(None)))
        nor = ~(va | vb)
        maj = (va & vb) | (va & vc) | (vb & vc)
        res = jnp.where(
            op == OP_NOR, nor,
            jnp.where(op == OP_MAJ3, maj,
                      jnp.where(op == OP_NOT, ~va,
                                jnp.where(op == OP_INIT0, jnp.zeros_like(nor),
                                          jnp.where(op == OP_INIT1,
                                                    jnp.full_like(nor, UMAX32),
                                                    va)))),
        )
        pl.store(state, (pl.dslice(o, 1), slice(None)), res)
        return 0

    jax.lax.fori_loop(0, n_gates, body, 0)

    for i, col in enumerate(output_slots):
        out_ref[i, :] = state[col, :]


@functools.partial(jax.jit, static_argnames=("schedule_key", "gen", "interpret"))
def _run(op, a, b, c, o, planes, *, schedule_key, gen, interpret):
    # `gen` bumps when a different schedule is registered under this key, so
    # traces that baked the old static slot maps are never reused.
    compiled = _SCHEDULES[schedule_key]
    input_slots = compiled.input_slots
    output_slots = compiled.output_slots
    n_in, W = planes.shape
    n_out = len(output_slots)
    grid = (W // BLOCK_WORDS,)
    return pl.pallas_call(
        functools.partial(_kernel, input_slots=tuple(input_slots), output_slots=tuple(output_slots)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((op.shape[0],), lambda i: (0,)),
            pl.BlockSpec((a.shape[0],), lambda i: (0,)),
            pl.BlockSpec((b.shape[0],), lambda i: (0,)),
            pl.BlockSpec((c.shape[0],), lambda i: (0,)),
            pl.BlockSpec((o.shape[0],), lambda i: (0,)),
            pl.BlockSpec((n_in, BLOCK_WORDS), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n_out, BLOCK_WORDS), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n_out, W), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((compiled.num_cols, BLOCK_WORDS), jnp.uint32)],
        interpret=interpret,
    )(op, a, b, c, o, planes)


# ---------------------------------------------------------------------------
# Wave-scheduled straight-line kernel (the `unrolled` mode)
# ---------------------------------------------------------------------------


def _wave_chunks(rows):
    """Greedy hazard-free chunking of allocated schedule rows.

    A gate joins the current chunk while it reads no column written earlier
    in the chunk (and does not re-write one).  All reads of a chunk then see
    pre-chunk state, so the generated read-then-write code — every result
    computed before any column is stored — is exactly program-order
    semantics, and each chunk is a batch of mutually independent VPU ops
    (the executable counterpart of ``ir.levelize``'s dependency waves;
    wave-major schedules chunk at full wave width).
    """
    chunks: list[list[tuple[int, int, int, int, int]]] = []
    cur: list[tuple[int, int, int, int, int]] = []
    written: set[int] = set()
    for row in rows:
        op, a, b, c, o = row
        reads = {(a, b, c)[s] for s in operand_slots(op)}
        if cur and (reads & written or o in written):
            chunks.append(cur)
            cur, written = [], set()
        cur.append(row)
        written.add(o)
    if cur:
        chunks.append(cur)
    return chunks


def _segments(compiled: ir.CompiledSchedule):
    """Wave chunks grouped into straight-line segments of at most
    ``UNROLL_SEGMENT_GATES`` gates (chunk boundaries are never split)."""
    rows = [tuple(int(x) for x in row) for row in compiled.ops]
    segments: list[list[list[tuple[int, int, int, int, int]]]] = [[]]
    count = 0
    for chunk in _wave_chunks(rows):
        if count and count + len(chunk) > UNROLL_SEGMENT_GATES:
            segments.append([])
            count = 0
        segments[-1].append(chunk)
        count += len(chunk)
    return segments


def _emit_chunks(cols, chunks):
    """Generate the straight-line body: per chunk, compute every gate from
    pre-chunk column values, then commit the writes.  ``cols`` is a Python
    list of per-column arrays/ref-reads, so the emitted jaxpr is pure SSA
    dataflow — no dynamic indexing and no opcode select survive tracing."""
    zero = None
    for chunk in chunks:
        results = []
        for op, a, b, c, o in chunk:
            if op == OP_NOR:
                r = ~(cols[a] | cols[b])
            elif op == OP_MAJ3:
                r = (cols[a] & cols[b]) | (cols[a] & cols[c]) | (cols[b] & cols[c])
            elif op == OP_NOT:
                r = ~cols[a]
            elif op == OP_INIT0:
                if zero is None:
                    zero = jnp.zeros_like(cols[0])
                r = zero
            elif op == OP_INIT1:
                r = jnp.full_like(cols[0], UMAX32)
            else:  # OP_COPY
                r = cols[a]
            results.append((o, r))
        for o, r in results:
            cols[o] = r


def _unrolled_segment_kernel(state_ref, out_ref, *, chunks, num_cols):
    cols = [state_ref[i, :] for i in range(num_cols)]
    _emit_chunks(cols, chunks)
    for i in range(num_cols):
        out_ref[i, :] = cols[i]


@functools.partial(jax.jit,
                   static_argnames=("schedule_key", "gen", "seg", "interpret"),
                   donate_argnums=0)
def _run_unrolled_segment(state, *, schedule_key, gen, seg, interpret):
    # `gen` bumps when a different schedule is registered under this key, so
    # traces that baked the old gate list are never reused.
    chunks = _segment_cache(schedule_key)[seg]
    num_cols, W = state.shape
    if interpret:
        # Same generated body, plain jit: pallas_call's interpret emulation
        # only adds per-op tracing cost on CPU.
        cols = [state[i] for i in range(num_cols)]
        _emit_chunks(cols, chunks)
        return jnp.stack(cols)
    return pl.pallas_call(
        functools.partial(_unrolled_segment_kernel, chunks=chunks,
                          num_cols=num_cols),
        grid=(W // BLOCK_WORDS,),
        in_specs=[pl.BlockSpec((num_cols, BLOCK_WORDS), lambda i: (0, i))],
        out_specs=pl.BlockSpec((num_cols, BLOCK_WORDS), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((num_cols, W), jnp.uint32),
        input_output_aliases={0: 0},
        interpret=False,
    )(state)


def _run_unrolled(compiled: ir.CompiledSchedule, key: str, planes, interpret):
    gen = _GENERATIONS.get(key, 0)
    state = jnp.zeros((compiled.num_cols, planes.shape[1]), jnp.uint32)
    state = state.at[jnp.asarray(compiled.input_slots)].set(
        jnp.asarray(planes, jnp.uint32))
    for seg in range(len(_segment_cache(key))):
        state = _run_unrolled_segment(
            state, schedule_key=key, gen=gen, seg=seg, interpret=interpret)
    return state[jnp.asarray(compiled.output_slots)]


# ---------------------------------------------------------------------------
# Per-schedule caches and dispatch
# ---------------------------------------------------------------------------

# Registry of compiled schedules (keyed so jit can treat them as static).
_SCHEDULES: dict[str, ir.CompiledSchedule] = {}
# Device-resident gate arrays for the loop kernel, built/uploaded once per
# key instead of per call.
_GATE_ARRAYS: dict[str, tuple] = {}
# Wave-chunked straight-line segments for the unrolled kernel.
_SEGMENTS: dict[str, list] = {}
# Bumped when a key is rebound to different schedule content; part of the
# kernels' static jit keys, so stale traces are never replayed.
_GENERATIONS: dict[str, int] = {}


def _invalidate(key: str) -> None:
    _GATE_ARRAYS.pop(key, None)
    _SEGMENTS.pop(key, None)
    _GENERATIONS[key] = _GENERATIONS.get(key, 0) + 1


def _gate_arrays(key: str) -> tuple:
    arrays = _GATE_ARRAYS.get(key)
    if arrays is None:
        arrays = _GATE_ARRAYS[key] = tuple(
            jax.device_put(a) for a in _SCHEDULES[key].as_arrays())
    return arrays


def _segment_cache(key: str) -> list:
    segments = _SEGMENTS.get(key)
    if segments is None:
        segments = _SEGMENTS[key] = _segments(_SCHEDULES[key])
    return segments


def register_compiled(compiled: ir.CompiledSchedule, key: str | None = None) -> str:
    key = key or compiled.key
    if _SCHEDULES.get(key) is not compiled:
        _invalidate(key)
    _SCHEDULES[key] = compiled
    return key


def register_schedule(key: str, schedule: Schedule | ir.CompiledSchedule) -> None:
    """Register a schedule under ``key``.  Accepts a ``CompiledSchedule`` or a
    legacy (column-allocated) ``machine.Schedule``, which is wrapped as-is."""
    if isinstance(schedule, ir.CompiledSchedule):
        register_compiled(schedule, key)
        return
    _invalidate(key)
    _SCHEDULES[key] = ir.CompiledSchedule.from_legacy(schedule, key=key)


def resolve_mode(compiled: ir.CompiledSchedule, mode: str = "auto") -> str:
    """``auto`` picks by gate count; ``unrolled``/``loop`` force a kernel."""
    if mode == "auto":
        return ("unrolled" if compiled.num_gates <= UNROLL_AUTO_MAX_GATES
                else "loop")
    if mode not in ("unrolled", "loop"):
        raise ValueError(f"unknown executor mode {mode!r} "
                         "(expected 'auto', 'unrolled' or 'loop')")
    return mode


def run_schedule(key: str, planes: jnp.ndarray, interpret: bool = True,
                 mode: str = "auto") -> jnp.ndarray:
    """Execute registered schedule ``key`` over stacked input planes.

    planes: ``[n_inputs, W]`` uint32 — inputs concatenated in sorted-name
    order (matching ``CompiledSchedule.input_slots``).  Returns
    ``[n_outputs, W]``.  W is padded to a BLOCK_WORDS multiple internally.
    ``mode`` selects the kernel: ``auto`` (by gate count), ``unrolled``
    (wave-scheduled straight line) or ``loop`` (fori_loop dispatch).
    """
    compiled = _SCHEDULES[key]
    if planes.shape[0] != len(compiled.input_slots):
        expected = {name: len(cols)
                    for name, cols in sorted(compiled.input_cols.items())}
        raise ValueError(
            f"schedule {key!r} expects {len(compiled.input_slots)} stacked "
            f"input planes ({expected}, in sorted-name order), got "
            f"{planes.shape[0]}")
    W = planes.shape[1]
    pad = (-W) % BLOCK_WORDS
    if pad:
        planes = jnp.pad(planes, ((0, 0), (0, pad)))
    if resolve_mode(compiled, mode) == "unrolled":
        out = _run_unrolled(compiled, key, planes, interpret)
    else:
        op, a, b, c, o = _gate_arrays(key)
        out = _run(op, a, b, c, o, planes, schedule_key=key,
                   gen=_GENERATIONS.get(key, 0), interpret=interpret)
    return out[:, :W]


class PallasBackend(ir.Backend):
    """TPU executor: one VMEM-resident crossbar per word-block, kernel mode
    chosen by gate count (interpret mode executes the same generated gate
    sequence on CPU).  ``opts['mode']`` overrides the selection per call."""

    name = "pallas"
    mode = "auto"

    def run(self, compiled, planes=None, interpret: bool = True,
            mode: str | None = None, **opts):
        if planes is None:
            raise ValueError(f"{self.name} backend needs input planes")
        key = register_compiled(compiled)
        out = run_schedule(key, planes, interpret=interpret,
                           mode=mode or self.mode)
        return ir.ExecutionResult(out, self.cost(compiled))


class PallasUnrolledBackend(PallasBackend):
    """Forces the wave-scheduled straight-line kernel regardless of size."""

    name = "pallas-unrolled"
    mode = "unrolled"


class PallasLoopBackend(PallasBackend):
    """Forces the fori_loop kernel (the unrolled mode's perf baseline)."""

    name = "pallas-loop"
    mode = "loop"


ir.register_backend(PallasBackend())
ir.register_backend(PallasUnrolledBackend())
ir.register_backend(PallasLoopBackend())
