"""Pure-jnp oracles for every Pallas kernel (per-kernel allclose targets)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import aritpim, bitplanes
from repro.core.machine import PlaneVM, Schedule, execute_schedule


def bitserial_ref(schedule: Schedule, planes: jnp.ndarray) -> jnp.ndarray:
    """Oracle for pim_bitserial: scan-based schedule execution on packed planes.

    planes: [n_inputs, W] stacked in sorted input-name order."""
    names = sorted(schedule.input_cols)
    split = {}
    i = 0
    for n in names:
        k = len(schedule.input_cols[n])
        split[n] = [planes[i + j] for j in range(k)]
        i += k
    out = execute_schedule(schedule, split, n_words=planes.shape[1])
    names_out = sorted(schedule.output_cols)
    return jnp.stack([p for n in names_out for p in out[n]])


def float_add_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Semantic oracle: IEEE-754 float32 addition (XLA scalar add)."""
    return (x.astype(jnp.float32) + y.astype(jnp.float32)).astype(jnp.float32)


def float_mul_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return (x.astype(jnp.float32) * y.astype(jnp.float32)).astype(jnp.float32)


def fixed_add_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return (x.astype(jnp.int32) + y.astype(jnp.int32)).astype(jnp.int32)


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Oracle for pim_matmul: batched jnp einsum with fp32 accumulation."""
    return jnp.einsum(
        "gmk,gkn->gmn", a, b, preferred_element_type=jnp.float32
    ).astype(a.dtype)
