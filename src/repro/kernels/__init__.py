"""Pallas TPU kernels: pim_bitserial (gate-schedule executor) and pim_matmul
(MatPIM-schedule blocked matmul), with ops.py wrappers and ref.py oracles."""
