"""Pallas TPU kernel: MatPIM-style blocked matmul (paper §4, ref [9]).

MatPIM expresses matrix multiplication as a serial sequence of vectored
(row-parallel) operations: for each k, a rank-1 update C += A[:,k] ⊗ B[k,:]
executes element-parallel across all crossbar rows.  The TPU-native analogue
keeps the *blocked data movement* structure (operand tiles resident in VMEM,
serial accumulation over the contraction dimension) but lets the MXU do the
inner product — this is the "adapt the insight, not the artifact" port
(DESIGN.md §2): the scheduling/blocking layer is the paper's, the arithmetic
unit is the hardware's.

The kernel doubles as the framework's general batched-matmul primitive and is
the shape the §Perf iterations tune (block sizes are MXU-aligned multiples of
128).  The PIM cost model for the same operation (gate-level, bit-serial) is
produced by ``repro.core.analyzer`` — benchmarks compare the two.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BK = 128
DEFAULT_BN = 128


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "interpret")
)
def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched matmul ``[G, M, K] @ [G, K, N] -> [G, M, N]`` (fp32 accumulate).

    Grid: (G·M/bm, N/bn, K/bk); K innermost so the fp32 accumulator tile in
    VMEM scratch is revisited serially — the MatPIM serial-accumulation
    schedule."""
    G, M, K = a.shape
    G2, K2, N = b.shape
    assert G == G2 and K == K2
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (a.shape, b.shape, bm, bk, bn)
    n_k = K // bk
    grid = (G * (M // bm), N // bn, n_k)
    m_blocks = M // bm

    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gm, n, k: (gm // m_blocks, gm % m_blocks, k)),
            pl.BlockSpec((1, bk, bn), lambda gm, n, k: (gm // m_blocks, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gm, n, k: (gm // m_blocks, gm % m_blocks, n)),
        out_shape=jax.ShapeDtypeStruct((G, M, N), a.dtype),
        scratch_shapes=[_vmem_scratch(bm, bn)],
        interpret=interpret,
    )(a, b)


def _vmem_scratch(bm: int, bn: int):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM((bm, bn), jnp.float32)
