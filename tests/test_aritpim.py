"""Bit-serial arithmetic: exhaustive small-N, property tests vs IEEE-754,
gate-count fidelity to the paper, crossbar column budget."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is optional: fall back to deterministic seeded cases
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hyp import given, settings, st

from repro.core import aritpim, bitplanes, simulate
from repro.core.machine import PlaneVM, execute_schedule

np.seterr(all="ignore")


# ------------------------------------------------------------ gate netlists

def test_full_adder_exhaustive():
    vm = PlaneVM(mode="execute", n_words=1)
    for a, b, c in itertools.product([0, 1], repeat=3):
        mk = lambda v: jnp.asarray([0xFFFFFFFF if v else 0], jnp.uint32)
        s, co = vm.full_adder(mk(a), mk(b), mk(c))
        assert (int(s[0]) & 1) == (a ^ b ^ c)
        assert (int(co[0]) & 1) == int(a + b + c >= 2)


def test_fixed_add_gate_count_matches_paper():
    # paper §3: 9 gates per bit, N=32 → 288
    assert aritpim.count_gates(aritpim.fixed_add, 32, 32) == 288


def test_fixed_mul_gate_count_near_paper():
    g = aritpim.count_gates(aritpim.fixed_mul_unsigned, 32, 32)
    assert abs(g - 10 * 32 * 32) / (10 * 32 * 32) < 0.15  # ≈10N² (paper §3)


def test_schedules_fit_crossbar_columns():
    # operands + intermediates must fit the paper's 1024-column crossbar
    for op in ("fixed_add", "float_add", "float_mul"):
        s = aritpim.build_schedule(op, compress=True)
        assert s.num_cols <= 1024, (op, s.num_cols)


def test_compressed_schedule_equivalence():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2**32, 96, dtype=np.uint64).astype(np.uint32).view(np.float32)
    y = rng.integers(0, 2**32, 96, dtype=np.uint64).astype(np.uint32).view(np.float32)
    s = aritpim.build_schedule("float_add", compress=True)
    out = execute_schedule(
        s,
        {"a": bitplanes.f32_to_planes(jnp.asarray(x)),
         "b": bitplanes.f32_to_planes(jnp.asarray(y))},
        n_words=3,
    )
    got = np.asarray(bitplanes.planes_to_f32(out["out"], 96))
    exp = (x + y).astype(np.float32)
    ok = (got.view(np.uint32) == exp.view(np.uint32)) | (np.isnan(got) & np.isnan(exp))
    assert ok.all()


# --------------------------------------------------------------- bit-planes

@given(st.integers(1, 200), st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, n).astype(bool)
    packed = bitplanes.pack_bits(jnp.asarray(bits))
    assert np.array_equal(np.asarray(bitplanes.unpack_bits(packed, n)), bits)
    assert np.array_equal(np.asarray(packed), bitplanes.np_pack_reference(bits.astype(np.uint8)))


# ------------------------------------------------------------- fixed point

def test_fixed_add_exhaustive_small():
    xs = np.arange(-8, 8, dtype=np.int32)
    X, Y = np.meshgrid(xs, xs)
    X, Y = X.ravel(), Y.ravel()
    vm = PlaneVM(mode="execute", n_words=bitplanes.num_words(len(X)))
    S = aritpim.fixed_add(vm, bitplanes.int_to_planes(jnp.asarray(X), 4),
                          bitplanes.int_to_planes(jnp.asarray(Y), 4))
    got = np.asarray(bitplanes.planes_to_int(S, len(X)))
    exp = ((X + Y) & 0xF)
    exp = np.where(exp >= 8, exp - 16, exp)
    assert np.array_equal(got, exp)


def test_fixed_mul_signed_exhaustive_small():
    xs = np.arange(-8, 8, dtype=np.int32)
    X, Y = np.meshgrid(xs, xs)
    X, Y = X.ravel(), Y.ravel()
    vm = PlaneVM(mode="execute", n_words=bitplanes.num_words(len(X)))
    P = aritpim.fixed_mul_signed(vm, bitplanes.int_to_planes(jnp.asarray(X), 4),
                                 bitplanes.int_to_planes(jnp.asarray(Y), 4))
    got = np.asarray(bitplanes.planes_to_int(P, len(X)))
    exp = (X.astype(np.int64) * Y.astype(np.int64)) & 0xFF
    exp = np.where(exp >= 128, exp - 256, exp).astype(np.int32)
    assert np.array_equal(got, exp)


def test_fixed_add32_random():
    rng = np.random.default_rng(1)
    x = rng.integers(-2**31, 2**31, 257, dtype=np.int64).astype(np.int32)
    y = rng.integers(-2**31, 2**31, 257, dtype=np.int64).astype(np.int32)
    got, cost = simulate.fixed_add(x, y)
    exp = (x.astype(np.int64) + y.astype(np.int64)).astype(np.int32)
    assert np.array_equal(np.asarray(got), exp)
    assert cost.gates == 288 and abs(cost.compute_complexity - 3.0) < 1e-9


# ----------------------------------------------------------- floating point

N_VEC = 256
_f32_vec = st.lists(
    st.integers(0, 2**32 - 1), min_size=N_VEC, max_size=N_VEC
).map(lambda xs: np.asarray(xs, np.uint64).astype(np.uint32).view(np.float32))


def _check_f32(got, exp):
    gb, eb = np.asarray(got).view(np.uint32), exp.view(np.uint32)
    ok = (gb == eb) | (np.isnan(np.asarray(got)) & np.isnan(exp))
    assert ok.all(), f"{(~ok).sum()} ULP mismatches"


@given(_f32_vec, _f32_vec)
@settings(max_examples=8, deadline=None)
def test_float_add_bit_exact(x, y):
    got, cost = simulate.float_add(x, y)
    _check_f32(got, (x + y).astype(np.float32))
    # deterministic netlist: execute-mode count equals the recorded one
    assert cost.gates == aritpim.count_gates(aritpim.float_add, 32, 32)


@given(_f32_vec, _f32_vec)
@settings(max_examples=6, deadline=None)
def test_float_mul_bit_exact(x, y):
    got, _ = simulate.float_mul(x, y)
    _check_f32(got, (x * y).astype(np.float32))


def test_float_specials_and_subnormals():
    specials = np.array(
        [0.0, -0.0, np.inf, -np.inf, np.nan, 1.0, -1.0, 1e-45, -1e-45,
         3.4e38, 1.17549435e-38, 5.877e-39], dtype=np.float32)
    X, Y = np.meshgrid(specials, specials)
    X, Y = X.ravel(), Y.ravel()
    got, _ = simulate.float_add(X, Y)
    _check_f32(got, (X + Y).astype(np.float32))
    got, _ = simulate.float_mul(X, Y)
    _check_f32(got, (X * Y).astype(np.float32))


def test_float_add_cancellation_paths():
    # massive-cancellation and near-magnitude subtraction (sticky-borrow path)
    rng = np.random.default_rng(5)
    a = rng.normal(size=300).astype(np.float32)
    b = (-a * (1 + np.float32(2.0) ** rng.integers(-24, 0, 300))).astype(np.float32)
    got, _ = simulate.float_add(a, b)
    _check_f32(got, (a + b).astype(np.float32))


def test_fixed_div_exhaustive_small():
    xs = np.arange(-8, 8, dtype=np.int32)
    ys = np.array([v for v in range(-8, 8) if v != 0], dtype=np.int32)
    X, Y = np.meshgrid(xs, ys)
    X, Y = X.ravel(), Y.ravel()
    vm = PlaneVM(mode="execute", n_words=bitplanes.num_words(len(X)))
    Q, R = aritpim.fixed_div_signed(
        vm, bitplanes.int_to_planes(jnp.asarray(X), 4),
        bitplanes.int_to_planes(jnp.asarray(Y), 4))
    gq = np.asarray(bitplanes.planes_to_int(Q, len(X)))
    gr = np.asarray(bitplanes.planes_to_int(R, len(X)))
    eq = (np.abs(X) // np.abs(Y)) * np.sign(X) * np.sign(Y)  # C truncation
    er = X - eq * Y
    eq = np.where(eq == 8, -8, eq)  # -8/-1 wraps in 4 bits
    assert np.array_equal(gq, eq.astype(np.int32))
    assert np.array_equal(gr, er.astype(np.int32))


@given(_f32_vec, _f32_vec)
@settings(max_examples=4, deadline=None)
def test_float_div_bit_exact(x, y):
    got, _ = simulate.float_div(x, y)
    _check_f32(got, (x / y).astype(np.float32))


def test_float_div_specials():
    sp = np.array([0.0, -0.0, np.inf, -np.inf, np.nan, 1.0, -1.0, 1e-45,
                   3.4e38, 1.17549435e-38], dtype=np.float32)
    X, Y = np.meshgrid(sp, sp)
    got, _ = simulate.float_div(X.ravel(), Y.ravel())
    _check_f32(got, (X.ravel() / Y.ravel()).astype(np.float32))


def test_div_schedules_fit_crossbar():
    for op in ("fixed_div", "float_div"):
        s = aritpim.build_schedule(op, compress=True)
        assert s.num_cols <= 1024, (op, s.num_cols)
