"""Executor modes and gate scheduling (DESIGN.md §5): bit-exactness of the
wave-scheduled ``pallas-unrolled`` kernel vs the ``pallas-loop`` fori_loop
kernel vs the interpreter oracle — across the ``_OP_TABLE``, fused MAC
programs, both logic bases and every frontend dtype — plus the
``levelize``/``reorder`` pass invariants (topological order preserved, peak
columns never increased) and the per-key schedule artifact caches."""

import numpy as np
import pytest

import jax.numpy as jnp

import repro.pim as pim
from repro.core import aritpim, ir
from repro.core.machine import operand_slots
from repro.kernels import pim_bitserial

np.seterr(all="ignore")

# Forced-unrolled parity is bounded: straight-line XLA-CPU compile time is
# superlinear in schedule length, and schedules past the auto threshold fall
# back to the loop kernel in production anyway (which the same test still
# checks).  The bound still covers every opcode on both bases and
# multi-segment straight-line kernels (> UNROLL_SEGMENT_GATES gates).
_UNROLL_TEST_CAP = 2500

_STRIPPED = tuple(p for p in ir.DEFAULT_PASSES if p != "reorder")

_MAC = lambda a, b, c: a * b + c  # noqa: E731


def _basis_nbits(op: str) -> int:
    if op.startswith("fixed"):
        return 8
    return 16 if op.startswith("bf16") else 32


def _random_planes(n_planes, n_words, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, 2**32, (n_planes, n_words), dtype=np.uint64).astype(np.uint32)
    )


# ------------------------------------------------------------ mode parity


@pytest.mark.parametrize("basis", ["memristive", "dram"])
@pytest.mark.parametrize("op", sorted(aritpim._OP_TABLE))
def test_executor_modes_bit_exact_all_ops(op, basis):
    """Acceptance: on both bases, every _OP_TABLE op executes bit-for-bit
    identically on the loop kernel, the unrolled kernel (size-capped — past
    the cap the auto selector must pick the loop kernel) and the
    interpreter, at the plane level on random bit patterns."""
    nbits = _basis_nbits(op)
    compiled = ir.compile_op(op, nbits, basis=basis)
    wa, wb = aritpim._OP_TABLE[op].in_widths(nbits)
    planes = _random_planes(wa + wb, 2, seed=sum(map(ord, op + basis)))
    exp = np.asarray(ir.get_backend("interpreter").run(compiled, planes).planes)

    got_loop = np.asarray(
        ir.get_backend("pallas-loop").run(compiled, planes).planes)
    assert np.array_equal(got_loop, exp), (op, basis, "loop")

    if compiled.num_gates <= _UNROLL_TEST_CAP:
        got_unrolled = np.asarray(
            ir.get_backend("pallas-unrolled").run(compiled, planes).planes)
        assert np.array_equal(got_unrolled, exp), (op, basis, "unrolled")
    else:
        assert pim_bitserial.resolve_mode(compiled) == "loop", (
            op, compiled.num_gates)

    got_auto = np.asarray(
        ir.get_backend("pallas").run(compiled, planes).planes)
    assert np.array_equal(got_auto, exp), (op, basis, "auto")


_DTYPES = {"int8": pim.int8, "int16": pim.int16, "bf16": pim.bf16}


@pytest.mark.parametrize("basis", ["memristive", "dram"])
@pytest.mark.parametrize("dtype", sorted(_DTYPES))
def test_fused_mac_unrolled_bit_exact(dtype, basis):
    """Fused multi-op MAC programs run the straight-line kernel bit-exactly
    (these schedules span several straight-line segments)."""
    dt = _DTYPES[dtype]
    mac = pim.compile(_MAC, dtype=dt)
    rng = np.random.default_rng(sum(map(ord, dtype + basis)))
    if dt.kind == "fixed":
        lo, hi = -(2 ** (dt.nbits - 1)), 2 ** (dt.nbits - 1)
        args = [jnp.asarray(rng.integers(lo, hi, 70).astype(np.int32))
                for _ in range(3)]
    else:
        bits = [rng.integers(0, 2**16, 70, dtype=np.uint32) for _ in range(3)]
        args = [jnp.asarray(b.astype(np.uint16)).view(jnp.bfloat16) for b in bits]
    got_u = mac(*args, basis=basis, backend="pallas-unrolled")
    got_l = mac(*args, basis=basis, backend="pallas-loop")
    got_i = mac(*args, basis=basis, backend="interpreter")
    vu, vl, vi = (
        np.asarray(x).view(np.uint16) if dt.kind == "bf16" else np.asarray(x)
        for x in (got_u, got_l, got_i))
    assert np.array_equal(vu, vi), (dtype, basis)
    assert np.array_equal(vl, vi), (dtype, basis)


def test_fused_f32_mac_unrolled_bit_exact():
    """The flagship 13k-gate f32 fused MAC: the forced straight-line kernel
    (multi-segment) reproduces the interpreter bit-for-bit.  One basis —
    this is the most expensive straight-line compile in the suite; the CI
    smoke perf gate races the same schedule."""
    mac = pim.compile(_MAC, dtype=pim.f32)
    rng = np.random.default_rng(7)
    args = [jnp.asarray(
        rng.integers(0, 2**32, 96, dtype=np.uint64).astype(np.uint32)
        .view(np.float32)) for _ in range(3)]
    got_u = np.asarray(mac(*args, backend="pallas-unrolled")).view(np.uint32)
    got_i = np.asarray(mac(*args, backend="interpreter")).view(np.uint32)
    assert np.array_equal(got_u, got_i)


# --------------------------------------------------- scheduling invariants


def _check_topological(sir: ir.ScheduleIR) -> None:
    defined = {v for cols in sir.inputs.values() for v in cols}
    for op, a, b, c, out in sir.ops:
        op, a, b, c, out = (int(x) for x in (op, a, b, c, out))
        for s in operand_slots(op):
            assert (a, b, c)[s] in defined, "operand used before definition"
        defined.add(out)


@pytest.mark.parametrize("op", ["fixed_add", "fixed_mul", "float_add"])
def test_levelize_preserves_topological_order(op):
    """Acceptance: wave-major reordering keeps every operand defined before
    use, waves are non-decreasing, and the wave count matches a direct
    recomputation of the DAG depth."""
    sir = ir.run_passes(ir.record_op(op, 32), (*_STRIPPED, "levelize"))
    _check_topological(sir)
    waves = ir._dataflow_waves(ir._gate_rows(sir))
    assert waves == sorted(waves)  # wave-major order
    assert sir.meta["num_waves"] == max(waves)


def test_levelize_preserves_semantics():
    x = np.array([3, -7, 120, -128], np.int32)
    y = np.array([5, 9, 100, -1], np.int32)
    compiled = ir.compile_op("fixed_add", 8, passes=(*_STRIPPED, "levelize"))
    from repro.core import bitplanes
    planes = jnp.stack(bitplanes.int_to_planes(jnp.asarray(x), 8)
                       + bitplanes.int_to_planes(jnp.asarray(y), 8))
    out = ir.get_backend("interpreter").run(compiled, planes).planes
    got = np.asarray(bitplanes.planes_to_int([out[i] for i in range(8)],
                                             len(x), signed=True))
    exp = ((x + y + 128) % 256 - 128).astype(np.int32)
    assert np.array_equal(got, exp)


@pytest.mark.parametrize("basis", ["memristive", "dram"])
@pytest.mark.parametrize("op", sorted(aritpim._OP_TABLE))
def test_reorder_never_increases_cols(op, basis):
    """Acceptance: the pressure scheduler never increases peak columns
    relative to the same pipeline without it, on either basis."""
    nbits = _basis_nbits(op)
    with_r = ir.compile_op(op, nbits, basis=basis)
    without = ir.compile_op(op, nbits, passes=_STRIPPED, basis=basis)
    assert with_r.num_cols <= without.num_cols, (op, basis)
    assert with_r.num_gates == without.num_gates  # pure reordering


def test_reorder_reduces_float_cols():
    """Acceptance: the scheduler strictly cuts peak columns for at least one
    float op (float_mul is the known win)."""
    wins = []
    for op, nbits in (("float_mul", 32), ("bf16_mul", 16)):
        with_r = ir.compile_op(op, nbits)
        without = ir.compile_op(op, nbits, passes=_STRIPPED)
        wins.append(with_r.num_cols < without.num_cols)
    assert any(wins)


def test_parallel_cycles_reported():
    from repro.core.costmodel import MEMRISTIVE_PIM

    rep = ir.op_cost("fixed_add", 8)
    assert 0 < rep.parallel_cycles <= rep.schedule_len
    assert MEMRISTIVE_PIM.report_parallel_throughput(rep) == (
        MEMRISTIVE_PIM.total_rows * MEMRISTIVE_PIM.clock_hz
        / rep.parallel_cycles)
    # a ripple adder has real parallelism: strictly fewer waves than rows
    assert rep.parallel_cycles < rep.schedule_len
    compiled = ir.compile_op("fixed_add", 8)
    assert rep.parallel_cycles == compiled.num_waves
    # reordering passes never change the DAG depth
    unsched = ir.op_cost("fixed_add", 8, passes=_STRIPPED)
    assert rep.parallel_cycles == unsched.parallel_cycles


# ------------------------------------------------- wave chunks & segments


def test_wave_chunks_hazard_free():
    """No gate in a chunk reads (or rewrites) a column written earlier in
    the same chunk — the invariant that makes read-then-write emission
    program-order-correct."""
    compiled = ir.compile_op("fixed_mul", 8)
    rows = [tuple(int(x) for x in r) for r in compiled.ops]
    chunks = pim_bitserial._wave_chunks(rows)
    assert sum(len(c) for c in chunks) == len(rows)
    for chunk in chunks:
        written = set()
        for op, a, b, c, o in chunk:
            reads = {(a, b, c)[s] for s in operand_slots(op)}
            assert not (reads & written)
            assert o not in written
            written.add(o)


def test_segments_respect_budget():
    compiled = ir.compile_op("float_mul", 32)
    segments = pim_bitserial._segments(compiled)
    assert len(segments) > 1  # float_mul is a genuine multi-segment case
    for seg in segments:
        n = sum(len(c) for c in seg)
        assert n <= pim_bitserial.UNROLL_SEGMENT_GATES or len(seg) == 1
    total = sum(len(c) for seg in segments for c in seg)
    assert total == compiled.num_gates


def test_auto_mode_threshold():
    small = ir.compile_op("fixed_add", 8)
    big = ir.compile_op("float_div", 32)
    assert small.num_gates <= pim_bitserial.UNROLL_AUTO_MAX_GATES
    assert pim_bitserial.resolve_mode(small) == "unrolled"
    assert pim_bitserial.resolve_mode(big) == "loop"
    assert pim_bitserial.resolve_mode(big, "unrolled") == "unrolled"
    with pytest.raises(ValueError, match="executor mode"):
        pim_bitserial.resolve_mode(small, "turbo")


# ------------------------------------------------------- schedule caches


def test_gate_arrays_cached_per_key():
    compiled = ir.compile_op("fixed_add", 8)
    key = pim_bitserial.register_compiled(compiled)
    a1 = pim_bitserial._gate_arrays(key)
    a2 = pim_bitserial._gate_arrays(key)
    assert a1 is a2  # built and uploaded once, reused
    # re-registering the same object keeps the cache ...
    pim_bitserial.register_compiled(compiled)
    assert pim_bitserial._gate_arrays(key) is a1
    # ... registering a different schedule under the key invalidates it
    clone = ir.compile_op("fixed_add", 8, passes=())
    pim_bitserial.register_schedule(key, clone)
    assert pim_bitserial._gate_arrays(key) is not a1
    pim_bitserial.register_compiled(compiled)  # restore


def test_run_schedule_plane_count_error():
    compiled = ir.compile_op("fixed_add", 8)
    key = pim_bitserial.register_compiled(compiled)
    planes = _random_planes(3, 2, seed=0)
    with pytest.raises(ValueError, match="expects 16 stacked input planes"):
        pim_bitserial.run_schedule(key, planes)


def test_rebound_key_does_not_replay_stale_kernel():
    """Re-registering different schedule content under an existing key must
    bump the generation so jit traces that baked the old gate list (or slot
    maps) are never replayed, in either executor mode."""
    from repro.core import bitplanes

    add = ir.compile_op("fixed_add", 8)
    sub = ir.compile_op("fixed_sub", 8)
    x = np.array([10, 7], np.int32)
    y = np.array([3, 2], np.int32)
    planes = jnp.stack(bitplanes.int_to_planes(jnp.asarray(x), 8)
                       + bitplanes.int_to_planes(jnp.asarray(y), 8))

    def run(mode):
        out = pim_bitserial.run_schedule("rebound", planes, mode=mode)
        return bitplanes.planes_to_int(
            [out[i] for i in range(8)], 2, signed=True).tolist()

    pim_bitserial.register_schedule("rebound", add)
    assert run("unrolled") == [13, 9] and run("loop") == [13, 9]
    pim_bitserial.register_schedule("rebound", sub)
    assert run("unrolled") == [7, 5] and run("loop") == [7, 5]
