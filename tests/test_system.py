"""End-to-end behaviour: train loop with checkpoint/restart + fault
injection, serve path, PIM offload analysis on a real compiled step —
the paper's pipeline from §3 arithmetic up to §5-style model benchmarks."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.analyzer import Workload, analyze
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import ServeEngine
from repro.launch.train import build_run, train_loop
from repro.runtime.fault_tolerance import FTConfig, FaultInjector


def test_train_loop_loss_decreases():
    cfg = get_smoke_config("stablelm_3b")
    mesh = make_host_mesh()
    run = build_run(cfg, mesh, optimizer_name="adamw-fast")
    stream = SyntheticStream(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, structure=0.9))
    run, hist = train_loop(run, stream, 30, log_every=1000)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, (first, last)


def test_train_restart_after_fault_resumes_from_checkpoint():
    cfg = get_smoke_config("musicgen_large")
    mesh = make_host_mesh()
    stream = SyntheticStream(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4))
    with tempfile.TemporaryDirectory() as d:
        run = build_run(cfg, mesh)
        injector = FaultInjector({12})
        run, hist = train_loop(
            run, stream, 20, ckpt_dir=d,
            ft=FTConfig(checkpoint_every=5, max_restarts=2),
            injector=injector, log_every=1000,
        )
        assert run.step == 20
        steps = [h["step"] for h in hist]
        assert 12 in steps  # the failed step was re-executed after restore
        from repro.checkpoint import store
        assert store.latest_step(d) == 20


def test_train_cold_resume():
    """A fresh process (new TrainRun) must continue from the checkpoint."""
    cfg = get_smoke_config("llama3_2_3b")
    mesh = make_host_mesh()
    stream = SyntheticStream(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4))
    with tempfile.TemporaryDirectory() as d:
        run1 = build_run(cfg, mesh)
        run1, _ = train_loop(run1, stream, 10, ckpt_dir=d,
                             ft=FTConfig(checkpoint_every=5), log_every=1000)
        run2 = build_run(cfg, mesh, seed=123)  # different init — must be overwritten
        run2, hist2 = train_loop(run2, stream, 15, ckpt_dir=d,
                                 ft=FTConfig(checkpoint_every=5), log_every=1000)
        assert run2.step == 15
        assert hist2[0]["step"] == 10  # resumed, not restarted


def test_serve_generates_batch():
    cfg = get_smoke_config("gemma2_27b")
    mesh = make_host_mesh()
    engine = ServeEngine.build(cfg, mesh, max_seq=24)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (3, 8)).astype(np.int32)
    out = engine.generate(prompts, 8, temperature=0.0)
    assert out.shape == (3, 16)
    assert (out[:, :8] == prompts).all()
    # greedy decoding is deterministic
    out2 = engine.generate(prompts, 8, temperature=0.0)
    assert (out == out2).all()


def test_offload_analyzer_on_compiled_step():
    """Wire a real compiled smoke train step into the Fig-8 analyzer."""
    cfg = get_smoke_config("stablelm_3b")
    from repro.launch import steps as steps_mod

    _, opt = steps_mod.choose_optimizer(cfg, "adamw")
    p = steps_mod.param_shapes(cfg)
    o = steps_mod.opt_state_shapes(opt, p)
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
        "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32),
    }
    c = jax.jit(steps_mod.make_train_step(cfg, opt)).lower(p, o, batch).compile()
    ca = c.cost_analysis()
    if isinstance(ca, list):  # older jax: one dict per computation
        ca = ca[0]
    w = Workload("smoke-train", flops=float(ca["flops"]),
                 hbm_bytes=float(ca.get("bytes accessed", 1.0)))
    v = analyze(w)
    assert v.tpu_time_s > 0 and v.pim_time_s > 0
