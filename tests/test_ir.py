"""Schedule-IR compiler pipeline: pass-by-pass bit-exactness vs the
execute-mode oracle, gate-count monotonicity, column-budget guarantees,
backend agreement (interpreter vs Pallas interpret), the new
int8/int16/bf16 ops through the same compilation path, and the multi-basis
(memristive NOR vs DRAM MAJ3/NOT) lowering invariants."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import aritpim, bitplanes, ir, simulate
from repro.core.machine import (
    OP_INIT0,
    OP_INIT1,
    OP_MAJ3,
    OP_NOR,
    OP_NOT,
    PlaneVM,
    get_basis,
)

np.seterr(all="ignore")

PASS_CONFIGS = [(), ("fold",), ("cse",), ("fuse",), ("dce",), ("reorder",),
                ("levelize",), ir.DEFAULT_PASSES]


def _f32_vec(n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32).view(np.float32)


def _run_f32(compiled, x, y, backend="interpreter"):
    planes = jnp.stack(
        bitplanes.f32_to_planes(jnp.asarray(x)) + bitplanes.f32_to_planes(jnp.asarray(y))
    )
    out = ir.get_backend(backend).run(compiled, planes).planes
    return np.asarray(bitplanes.planes_to_f32([out[i] for i in range(32)], len(x)))


def _check_f32(got, exp):
    ok = (got.view(np.uint32) == exp.view(np.uint32)) | (np.isnan(got) & np.isnan(exp))
    assert ok.all(), f"{(~ok).sum()} ULP mismatches"


# ------------------------------------------------------------------- passes

@pytest.mark.parametrize("basis", ["memristive", "dram"])
@pytest.mark.parametrize("passes", PASS_CONFIGS, ids=lambda p: "+".join(p) or "none")
@pytest.mark.parametrize("op", ["float_add", "float_mul"])
def test_each_pass_preserves_float_semantics(op, passes, basis):
    """Every pass (and the default pipeline) is semantics-preserving on both
    logic bases: the compiled schedule reproduces IEEE float32 bit-for-bit."""
    x, y = _f32_vec(96, 1), _f32_vec(96, 2)
    compiled = ir.compile_op(op, passes=passes, basis=basis)
    got = _run_f32(compiled, x, y)
    exp = (x + y if op == "float_add" else x * y).astype(np.float32)
    _check_f32(got, exp)


@pytest.mark.parametrize("op", ["fixed_add", "fixed_mul", "float_add", "float_mul",
                                "bf16_add", "bf16_mul"])
def test_pipeline_gate_count_non_increasing(op):
    """Acceptance: post-pipeline gate count ≤ recorded gate count, and each
    pass prefix never increases the schedule length."""
    nbits = 16 if op.startswith("bf16") else 32
    recorded = ir.record_op(op, nbits)
    prev = recorded.num_gates
    for k in range(1, len(ir.DEFAULT_PASSES) + 1):
        cur = ir.run_passes(recorded, ir.DEFAULT_PASSES[:k]).num_gates
        assert cur <= prev, (op, ir.DEFAULT_PASSES[:k], cur, prev)
        prev = cur
    compiled = ir.compile_op(op, nbits)
    assert compiled.num_gates <= compiled.recorded_len
    assert compiled.nor_gates <= compiled.recorded_gates


@pytest.mark.parametrize("op", ["fixed_add", "fixed_mul", "float_add", "float_mul"])
def test_pipeline_peak_columns_within_old_compress(op):
    """Acceptance: peak live columns ≤ the old compress_schedule result
    (= lowering the recorded schedule with no passes)."""
    baseline = ir.lower(ir.record_op(op))
    compiled = ir.compile_op(op)
    assert compiled.num_cols <= baseline.num_cols, (op, compiled.num_cols, baseline.num_cols)
    assert compiled.meta["baseline_cols"] == baseline.num_cols
    assert compiled.num_cols <= 1024  # the paper's crossbar budget


def test_fold_constants_unit():
    """NOR against a known constant folds to an INIT."""
    vm = PlaneVM(mode="record")
    a = vm.input_plane()
    one = vm.const1()
    zero = vm.const0()
    x = vm.nor(a, one)   # == 0
    y = vm.nor(zero, zero)  # == 1
    z = vm.nor(a, zero)  # == NOT a, stays a gate
    sched = vm.finish_schedule({"a": [a]}, {"out": [x, y, z]})
    folded = ir.fold_constants(ir.from_schedule(sched))
    ops = {int(o) for o in folded.ops[:, 0]}
    nors = folded.ops[folded.ops[:, 0] == OP_NOR]
    assert OP_INIT0 in ops and OP_INIT1 in ops
    assert len(nors) == 1  # only NOT(a) survives as a gate
    assert int(nors[0][1]) == int(nors[0][2])  # canonicalized to NOR(a, a)


def test_cse_unit():
    """Identical NORs (either operand order) collapse to one gate."""
    vm = PlaneVM(mode="record")
    a, b = vm.input_plane(), vm.input_plane()
    x = vm.nor(a, b)
    y = vm.nor(b, a)  # same value, swapped operands
    sched = vm.finish_schedule({"a": [a], "b": [b]}, {"out": [x, y]})
    out = ir.common_subexpr_elim(ir.from_schedule(sched))
    assert out.num_gates == 1
    o = out.outputs["out"]
    assert o[0] == o[1]  # both outputs alias the surviving value


def test_fuse_not_not_unit():
    """NOT(NOT(x)) folds to x itself (then DCE sweeps the dead NOTs)."""
    vm = PlaneVM(mode="record")
    a, b = vm.input_plane(), vm.input_plane()
    x = vm.nor(a, b)
    nn = vm.nor(vm.not_(x), vm.not_(x))  # NOT(NOT(x)): not-cache dedups the inner NOT
    sched = vm.finish_schedule({"a": [a], "b": [b]}, {"out": [nn]})
    fused = ir.dead_gate_elim(ir.fuse_copies(ir.from_schedule(sched)))
    assert fused.num_gates == 1  # only the original NOR remains
    assert fused.outputs["out"][0] == fused.ops[0][4]  # (op, a, b, c, out)


def test_dce_unit():
    vm = PlaneVM(mode="record")
    a, b = vm.input_plane(), vm.input_plane()
    keep = vm.nor(a, b)
    vm.nor(keep, a)  # dead: never reaches an output
    sched = vm.finish_schedule({"a": [a], "b": [b]}, {"out": [keep]})
    out = ir.dead_gate_elim(ir.from_schedule(sched))
    assert out.num_gates == 1


# ----------------------------------------------------------------- backends

def test_interpreter_and_pallas_agree_on_same_ir():
    """Both executors consume the identical optimized CompiledSchedule."""
    x, y = _f32_vec(257, 3), _f32_vec(257, 4)
    compiled = ir.compile_op("float_add")
    got_i = _run_f32(compiled, x, y, backend="interpreter")
    got_p = _run_f32(compiled, x, y, backend="pallas")
    assert np.array_equal(got_i.view(np.uint32), got_p.view(np.uint32))


def test_cost_backend_reports_compiled_counts():
    rep = ir.op_cost("float_add")
    compiled = ir.compile_op("float_add")
    assert rep.gates == compiled.nor_gates
    assert rep.recorded_gates == compiled.recorded_gates
    assert rep.schedule_len == compiled.num_gates
    assert rep.cycles == 2 * compiled.num_gates
    assert rep.num_cols == compiled.num_cols
    # the pipeline is actually optimizing, not a no-op
    assert rep.gates < rep.recorded_gates


def test_compile_cache_hits():
    a = ir.compile_op("fixed_add", 32)
    b = ir.compile_op("fixed_add", 32)
    assert a is b
    c = ir.compile_op("fixed_add", 32, passes=())
    assert c is not a and c.pass_log == ()


def test_backend_registry():
    names = ir.backend_names()
    assert "interpreter" in names and "cost" in names
    assert ir.get_backend("pallas").name == "pallas"


# ------------------------------------------------------- new dtypes (int/bf16)

@pytest.mark.parametrize("nbits", [8, 16])
def test_fixed_add_small_widths_compiled(nbits):
    from repro.kernels import ops

    rng = np.random.default_rng(nbits)
    lo, hi = -(2 ** (nbits - 1)), 2 ** (nbits - 1)
    x = rng.integers(lo, hi, 300, dtype=np.int64).astype(np.int32)
    y = rng.integers(lo, hi, 300, dtype=np.int64).astype(np.int32)
    got = np.asarray(ops.pim_fixed_add(x, y, nbits=nbits))
    mask = (1 << nbits) - 1
    exp = (x.astype(np.int64) + y.astype(np.int64)) & mask
    exp = np.where(exp >= hi, exp - (1 << nbits), exp).astype(np.int32)
    assert np.array_equal(got, exp)


@pytest.mark.parametrize("nbits", [8, 16])
def test_fixed_mul_small_widths_compiled(nbits):
    from repro.kernels import ops

    rng = np.random.default_rng(nbits + 100)
    lo, hi = -(2 ** (nbits - 1)), 2 ** (nbits - 1)
    x = rng.integers(lo, hi, 300, dtype=np.int64).astype(np.int32)
    y = rng.integers(lo, hi, 300, dtype=np.int64).astype(np.int32)
    got = np.asarray(ops.pim_fixed_mul(x, y, nbits=nbits))
    mask = (1 << nbits) - 1
    exp = (x.astype(np.int64) * y.astype(np.int64)) & mask
    exp = np.where(exp >= hi, exp - (1 << nbits), exp).astype(np.int32)
    assert np.array_equal(got, exp)


def _bf16_cases(seed, n=1024):
    import ml_dtypes

    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2**16, n, dtype=np.uint32).astype(np.uint16).view(ml_dtypes.bfloat16)
    sp = np.array([0.0, -0.0, np.inf, -np.inf, np.nan, 1.0, -1.0,
                   9.2e-41, 3.4e38, 1.18e-38], dtype=ml_dtypes.bfloat16)
    return np.concatenate([x, np.repeat(sp, len(sp))]), None


def _check_bf16(got, exp):
    import ml_dtypes

    gb = np.asarray(got).view(np.uint16)
    eb = np.asarray(exp, dtype=ml_dtypes.bfloat16).view(np.uint16)
    nan = np.isnan(np.asarray(got, np.float32)) & np.isnan(np.asarray(exp, np.float32))
    ok = (gb == eb) | nan
    assert ok.all(), f"{(~ok).sum()} bf16 mismatches"


@pytest.mark.parametrize("op", ["bf16_add", "bf16_mul"])
def test_bf16_bit_exact_through_pipeline(op):
    """bf16 add/mul through record→passes→Pallas(interpret), bit-exact vs the
    float64-exact computation rounded once to bf16 (RNE)."""
    import ml_dtypes

    from repro.kernels import ops

    x, _ = _bf16_cases(11)
    rng = np.random.default_rng(12)
    y = np.concatenate([
        rng.integers(0, 2**16, 1024, dtype=np.uint32).astype(np.uint16).view(ml_dtypes.bfloat16),
        np.tile(np.array([0.0, -0.0, np.inf, -np.inf, np.nan, 1.0, -1.0,
                          9.2e-41, 3.4e38, 1.18e-38], dtype=ml_dtypes.bfloat16), 10),
    ])
    xj = jnp.asarray(x.view(np.uint16)).view(jnp.bfloat16)
    yj = jnp.asarray(y.view(np.uint16)).view(jnp.bfloat16)
    fn = ops.pim_bf16_add if op == "bf16_add" else ops.pim_bf16_mul
    got = np.asarray(fn(xj, yj))
    ex64 = (x.astype(np.float64) + y.astype(np.float64)) if op == "bf16_add" \
        else (x.astype(np.float64) * y.astype(np.float64))
    _check_bf16(got, ex64)


def test_bf16_simulate_cost():
    x = np.array([1.5, -2.0, 3.25], dtype=np.float32)
    y = np.array([0.5, 4.0, -1.25], dtype=np.float32)
    res, cost = simulate.bf16_add(x, y)
    assert np.allclose(np.asarray(res, np.float32), x + y)
    assert cost.gates == aritpim.count_gates(aritpim.bf16_add, 16, 16)
    assert 0 < cost.optimized_gates <= cost.gates
    # bf16 add is far cheaper than float32 add — the point of the new dtype
    assert cost.gates < aritpim.count_gates(aritpim.float_add, 32, 32)


# ------------------------------------------------------------- oracle parity

def test_simulate_cost_matches_ir_cost():
    """simulate's OpCost and the analytical backend report the same netlist."""
    _, cost = simulate.float_add(np.ones(3, np.float32), np.ones(3, np.float32))
    rep = ir.op_cost("float_add")
    assert cost.gates == rep.recorded_gates
    assert cost.optimized_gates == rep.gates
    assert cost.peak_cols == rep.num_cols


def test_netlist_gate_counts_keys():
    from repro.core.analyzer import netlist_gate_counts

    g = netlist_gate_counts()
    assert g["fixed32_add"] == 288
    assert set(g) >= {"fixed32_add", "fixed32_mul", "float32_add", "float32_mul"}


# --------------------------------------------------- multi-basis (MAJ3/NOT)

def _basis_nbits(op):
    """Width keeping the all-ops sweep fast: fixed ops at 8 bits, floats at
    their fixed format widths."""
    if op.startswith("fixed"):
        return 8
    return 16 if op.startswith("bf16") else 32


def _random_planes(n_planes, n_words, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, 2**32, (n_planes, n_words), dtype=np.uint64).astype(np.uint32)
    )


@pytest.mark.parametrize("op", sorted(aritpim._OP_TABLE))
def test_all_ops_bit_exact_on_dram_basis(op):
    """Acceptance: every _OP_TABLE op compiles on the dram basis and the
    interpreter backend reproduces the execute-mode oracle bit-for-bit on
    random bit patterns (plane-level comparison, no decode)."""
    nbits = _basis_nbits(op)
    wa, wb, wout = aritpim.op_widths(op, nbits)
    n_words = 2
    planes = _random_planes(wa + wb, n_words, seed=sum(map(ord, op)))

    vm = PlaneVM(mode="execute", n_words=n_words)
    A = [planes[i] for i in range(wa)]
    B = [planes[wa + i] for i in range(wb)]
    exp = aritpim._OP_TABLE[op].builder(vm, A, B)
    assert len(exp) == wout
    exp = np.stack([np.asarray(p) for p in exp])

    compiled = ir.compile_op(op, nbits, basis="dram")
    assert compiled.basis == "dram"
    assert compiled.nor_gates == 0  # fully lowered out of the NOR basis
    got = np.asarray(ir.get_backend("interpreter").run(compiled, planes).planes)
    assert np.array_equal(got, exp), op


@pytest.mark.parametrize("nbits", [8, 16, 32])
def test_dram_fixed_add_maj_bound(nbits):
    """Acceptance: the ripple adder's MAJ3 count stays at or below the
    textbook majority-form full adder (3 MAJ per bit) — the FA rewrite fires
    instead of the naive per-NOR expansion."""
    rep = ir.op_cost("fixed_add", nbits, basis="dram")
    assert rep.maj_gates <= 3 * nbits, (rep.maj_gates, 3 * nbits)
    assert rep.gates == rep.maj_gates + rep.not_gates


@pytest.mark.parametrize("basis", ["memristive", "dram"])
@pytest.mark.parametrize("op", ["fixed_add", "fixed_mul", "float_add", "float_mul"])
def test_pipeline_invariants_both_bases(op, basis):
    """Acceptance: for both bases, passes never increase the native gate
    count relative to the pre-pass (basis-lowered) program, and peak columns
    stay within the unoptimized allocation and the crossbar budget."""
    pre = ir.record_op(op)
    if basis == "dram":
        pre = ir.lower_to_dram(pre)
    baseline = ir.lower(pre, basis=basis)
    compiled = ir.compile_op(op, basis=basis)
    assert compiled.native_gates <= pre.gate_count(basis)
    assert compiled.num_cols <= baseline.num_cols
    assert compiled.peak_rows <= 1024  # crossbar/subarray row budget
    assert compiled.meta["baseline_cols"] == baseline.num_cols


def test_dram_lowering_unit():
    """NOR(x', y') folds to MAJ(x, y, 0) and NOR(x, x) to NOT(x)."""
    vm = PlaneVM(mode="record")
    a, b = vm.input_plane(), vm.input_plane()
    na = vm.not_(a)
    nb = vm.not_(b)
    and_ab = vm.nor(na, nb)  # a AND b
    sched = vm.finish_schedule({"a": [a], "b": [b]}, {"out": [and_ab]})
    lowered = ir.dead_gate_elim(ir.lower_to_dram(ir.from_schedule(sched)))
    codes = [int(o) for o in lowered.ops[:, 0]]
    assert codes.count(OP_MAJ3) == 1
    assert OP_NOR not in codes


def test_dram_full_adder_rewrite_unit():
    """The recorded 9-NOR full adder lowers to 3 MAJ + 2 NOT."""
    vm = PlaneVM(mode="record")
    a, b, c = (vm.input_plane() for _ in range(3))
    s, co = vm.full_adder(a, b, c)
    sched = vm.finish_schedule({"a": [a], "b": [b], "c": [c]}, {"out": [s, co]})
    lowered = ir.lower_to_dram(ir.from_schedule(sched))
    codes = [int(o) for o in lowered.ops[:, 0]]
    assert codes.count(OP_MAJ3) == 3 and codes.count(OP_NOT) == 2
    assert OP_NOR not in codes


def test_maj3_execute_matches_interpreter():
    """PlaneVM.maj3 (execute) and the interpreter's OP_MAJ3 agree."""
    vm = PlaneVM(mode="record")
    a, b, c = (vm.input_plane() for _ in range(3))
    m = vm.maj3(a, b, c)
    sched = vm.finish_schedule({"a": [a], "b": [b], "c": [c]}, {"out": [m]})
    compiled = ir.lower(ir.from_schedule(sched), basis="dram")
    planes = _random_planes(3, 4, seed=5)
    got = np.asarray(ir.get_backend("interpreter").run(compiled, planes).planes[0])
    va, vb, vc = (np.asarray(planes[i]) for i in range(3))
    exp = (va & vb) | (va & vc) | (vb & vc)
    assert np.array_equal(got, exp)


def test_per_basis_compile_cache_distinct():
    m = ir.compile_op("fixed_add", 32)
    d = ir.compile_op("fixed_add", 32, basis="dram")
    assert m is not d
    assert m.basis == "memristive" and d.basis == "dram"
    assert d is ir.compile_op("fixed_add", 32, basis="dram")  # cached


def test_dram_cost_report_cycles():
    """Cost backend: dram cycles are the AAP/TRA weighted sum (5 per MAJ,
    2 per NOT, 1 per COPY/INIT), not schedule_len × cycles_per_gate."""
    rep = ir.op_cost("fixed_add", 32, basis="dram")
    compiled = ir.compile_op("fixed_add", 32, basis="dram")
    inits = compiled.num_gates - compiled.maj_gates - compiled.not_gates
    assert rep.basis == "dram"
    assert rep.cycles == 5 * rep.maj_gates + 2 * rep.not_gates + inits
    assert rep.peak_rows == compiled.num_cols + get_basis("dram").compute_rows
    assert rep.copy_aaps > 0
    # independently derived, but within 25% of the paper's clock-scaled
    # convention for the calibration op (576 cycles for fixed32_add)
    assert abs(rep.cycles - 576) / 576 < 0.25


def test_interpreter_and_pallas_agree_on_dram_basis():
    x, y = _f32_vec(257, 5), _f32_vec(257, 6)
    compiled = ir.compile_op("float_add", basis="dram")
    got_i = _run_f32(compiled, x, y, backend="interpreter")
    got_p = _run_f32(compiled, x, y, backend="pallas")
    assert np.array_equal(got_i.view(np.uint32), got_p.view(np.uint32))


# ------------------------------------------------- division (full pipeline)

@pytest.mark.parametrize("basis", ["memristive", "dram"])
@pytest.mark.parametrize("nbits", [8, 16])
def test_fixed_div_compiled_interpreter(nbits, basis):
    """fixed_div through the full compiled path (all default passes +
    interpreter backend), vs C truncation-toward-zero semantics."""
    rng = np.random.default_rng(nbits)
    lo, hi = -(2 ** (nbits - 1)), 2 ** (nbits - 1)
    x = rng.integers(lo, hi, 200, dtype=np.int64)
    y = rng.integers(lo, hi, 200, dtype=np.int64)
    y[y == 0] = 1  # divide-by-zero convention is tested at the oracle level
    planes = jnp.stack(
        bitplanes.int_to_planes(jnp.asarray(x.astype(np.int32)), nbits)
        + bitplanes.int_to_planes(jnp.asarray(y.astype(np.int32)), nbits)
    )
    compiled = ir.compile_op("fixed_div", nbits, basis=basis)
    out = ir.get_backend("interpreter").run(compiled, planes).planes
    got = np.asarray(
        bitplanes.planes_to_int([out[i] for i in range(nbits)], len(x), signed=True)
    )
    exp = (np.trunc(x / y)).astype(np.int64)
    exp = np.where(exp >= hi, exp - (1 << nbits), exp).astype(np.int32)  # INT_MIN/-1 wrap
    assert np.array_equal(got, exp)


@pytest.mark.parametrize("basis", ["memristive", "dram"])
def test_float_div_compiled_interpreter(basis):
    """float_div through the full compiled path, bit-exact vs IEEE division
    including specials (0, inf, nan, subnormals)."""
    rng = np.random.default_rng(9)
    x = rng.integers(0, 2**32, 64, dtype=np.uint64).astype(np.uint32).view(np.float32)
    y = rng.integers(0, 2**32, 64, dtype=np.uint64).astype(np.uint32).view(np.float32)
    sp = np.array([0.0, -0.0, np.inf, -np.inf, np.nan, 1.0, -1.0, 1e-40, 3.4e38],
                  dtype=np.float32)
    x = np.concatenate([x, np.repeat(sp, len(sp))])
    y = np.concatenate([y, np.tile(sp, len(sp))])
    compiled = ir.compile_op("float_div", basis=basis)
    got = _run_f32(compiled, x, y)
    with np.errstate(all="ignore"):
        exp = (x / y).astype(np.float32)
    _check_f32(got, exp)
