"""Deterministic fallback for ``hypothesis`` on bare environments.

Provides exactly the surface this suite uses — ``st.integers``,
``st.lists(...).map(...)``, ``@given`` and ``@settings(max_examples=,
deadline=)`` — drawing examples from a seeded RNG so every run sees the same
cases.  Decorator order must be ``@given`` above ``@settings`` (the order
used throughout this suite): ``settings`` stamps the example budget on the
test function and ``given`` reads it.
"""

from __future__ import annotations

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def example(self, rng):
        return self._draw(rng)


class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.example(rng) for _ in range(n)]

        return _Strategy(draw)


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        max_examples = getattr(fn, "_fallback_max_examples", 10)

        # Deliberately NOT functools.wraps: the wrapper must present a
        # zero-arg signature or pytest mistakes drawn params for fixtures.
        def wrapper():
            for i in range(max_examples):
                rng = np.random.default_rng(0xC0FFEE + i)
                drawn = [s.example(rng) for s in strategies]
                fn(*drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
