"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp ref oracles
(interpret=True executes the Pallas kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aritpim, bitplanes
from repro.kernels import ops, ref
from repro.kernels import pim_bitserial

np.seterr(all="ignore")


@pytest.mark.parametrize("n", [1, 31, 32, 33, 255, 1000])
def test_bitserial_float_add_sweep(n):
    rng = np.random.default_rng(n)
    x = rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32).view(np.float32)
    y = rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32).view(np.float32)
    got = np.asarray(ops.pim_float_add(x, y))
    exp = (x + y).astype(np.float32)
    ok = (got.view(np.uint32) == exp.view(np.uint32)) | (np.isnan(got) & np.isnan(exp))
    assert ok.all()


@pytest.mark.parametrize("nbits", [8, 16, 32])
def test_bitserial_fixed_add_sweep(nbits):
    rng = np.random.default_rng(nbits)
    lo, hi = -(2 ** (nbits - 1)), 2 ** (nbits - 1)
    x = rng.integers(lo, hi, 300, dtype=np.int64).astype(np.int32)
    y = rng.integers(lo, hi, 300, dtype=np.int64).astype(np.int32)
    got = np.asarray(ops.pim_fixed_add(x, y, nbits=nbits))
    mask = (1 << nbits) - 1
    exp = (x.astype(np.int64) + y.astype(np.int64)) & mask
    exp = np.where(exp >= hi, exp - (1 << nbits), exp).astype(np.int32)
    assert np.array_equal(got, exp)


def test_bitserial_matches_scan_oracle():
    """Pallas executor vs machine.execute_schedule on the same schedule."""
    key = "float_mul32"
    sched = aritpim.build_schedule("float_mul", compress=True)
    pim_bitserial.register_schedule(key, sched)
    rng = np.random.default_rng(7)
    x = rng.normal(size=64).astype(np.float32)
    y = rng.normal(size=64).astype(np.float32)
    planes = jnp.stack(
        bitplanes.f32_to_planes(jnp.asarray(x)) + bitplanes.f32_to_planes(jnp.asarray(y))
    )
    got = pim_bitserial.run_schedule(key, planes)
    oracle = ref.bitserial_ref(sched, planes)
    assert np.array_equal(np.asarray(got), np.asarray(oracle))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 128, 128, 128), (2, 256, 384, 512), (3, 128, 256, 128)])
def test_matmul_kernel_sweep(shape, dtype):
    G, M, K, N = shape
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(G, M, K)), dtype)
    b = jnp.asarray(rng.normal(size=(G, K, N)), dtype)
    got = ops.pim_matmul_op(a, b)
    exp = ref.matmul_ref(a, b)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(exp, np.float32), rtol=tol, atol=tol * 8
    )


@pytest.mark.parametrize("blocks", [(128, 128, 128), (64, 128, 256)])
def test_matmul_kernel_block_shapes(blocks):
    bm, bk, bn = blocks
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(1, 256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, 256, 256)), jnp.float32)
    got = ops.pim_matmul_op(a, b, bm=bm, bk=bk, bn=bn)
    exp = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=2e-4, atol=1e-3)


def test_schedule_info_reports_gates_and_columns():
    gates, cols = ops.schedule_info("fixed_add")
    assert gates >= 288 and cols <= 1024
