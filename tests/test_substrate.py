"""Substrate tests: optimizers, data pipeline, checkpointing, sharding rules,
fault-tolerance primitives, elastic planning."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is optional: fall back to deterministic seeded cases
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hyp import given, settings, st

from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.launch.mesh import make_abstract_mesh
from repro.data.pipeline import DataConfig, MemmapCorpus, SyntheticStream
from repro.checkpoint import store
from repro.optim import adamw, adafactor, clip_by_global_norm, global_norm, warmup_cosine
from repro.optim.adamw import apply_updates
from repro.parallel import sharding
from repro.runtime.elastic import plan_rescale
from repro.runtime.fault_tolerance import (
    FTConfig,
    FaultInjector,
    HeartbeatMonitor,
    RestartPolicy,
    StragglerDetector,
)

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------- optim

def test_adamw_converges_quadratic():
    opt = adamw(lr=0.1, weight_decay=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        up, state = opt.update(g, state, params)
        params = apply_updates(params, up)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.ones((64, 32)), "b": jnp.ones((7,))}
    st_ = opt.init(params)
    assert st_["s"]["w"]["vr"].shape == (64,)
    assert st_["s"]["w"]["vc"].shape == (32,)
    assert st_["s"]["b"]["v"].shape == (7,)
    g = jax.tree.map(jnp.ones_like, params)
    up, st2 = opt.update(g, st_, params)
    assert jax.tree.all(jax.tree.map(lambda u, p: u.shape == p.shape, up, params))


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(np.sqrt(90.0), rel=1e-5)


def test_warmup_cosine_shape():
    fn = warmup_cosine(1e-3, 10, 100)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert float(fn(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(fn(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)


# ------------------------------------------------------------------ data

def test_synthetic_stream_deterministic_and_resumable():
    s = SyntheticStream(DataConfig(vocab=100, seq_len=16, global_batch=4, seed=1))
    a, b = s.next_batch(5), s.next_batch(5)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = s.next_batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding partitions the global batch
    h0 = s.host_batch(5, 0, 2)["tokens"]
    h1 = s.host_batch(5, 1, 2)["tokens"]
    assert np.array_equal(np.concatenate([h0, h1]), a["tokens"])


def test_memmap_corpus():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tokens.bin")
        np.arange(10000, dtype=np.int32).tofile(path)
        c = MemmapCorpus(path, DataConfig(vocab=50000, seq_len=8, global_batch=2))
        b = c.next_batch(0)
        assert b["tokens"].shape == (2, 8)
        # labels are next-token shifted
        assert int(b["labels"][0, 0]) == int(b["tokens"][0, 1])


# ------------------------------------------------------------- checkpoint

def test_checkpoint_atomic_roundtrip_and_gc():
    tree = {"p": {"w": jnp.arange(12.0).reshape(3, 4)}, "step": jnp.asarray(3)}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            store.save(tree, d, s)
        assert store.latest_step(d) == 4
        # gc keeps 3
        names = sorted(os.listdir(d))
        assert len([n for n in names if n.startswith("step_")]) == 3
        back = store.restore(d, 4, jax.tree.map(jnp.zeros_like, tree))
        assert np.array_equal(back["p"]["w"], tree["p"]["w"])


def test_async_checkpointer():
    tree = {"w": jnp.ones((8, 8))}
    with tempfile.TemporaryDirectory() as d:
        ck = store.AsyncCheckpointer(d)
        ck.save(tree, 1)
        ck.wait()
        assert store.latest_step(d) == 1


def test_checkpoint_restores_subtree_and_defaults():
    with tempfile.TemporaryDirectory() as d:
        store.save({"a": jnp.ones((2,)), "b": jnp.zeros((3,))}, d, 1)
        like = {"a": jnp.zeros((2,)), "c": jnp.full((4,), 7.0)}  # c not in ckpt
        back = store.restore(d, 1, like)
        assert np.array_equal(back["a"], np.ones((2,)))
        assert np.array_equal(back["c"], np.full((4,), 7.0))


# -------------------------------------------------------------- sharding

MESH = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_param_specs_tp_rules():
    cfg = get_smoke_config("stablelm_3b")
    import dataclasses
    from repro.models import transformer as T

    shapes = jax.eval_shape(lambda k: T.init_params(k, cfg), KEY)
    specs = sharding.param_specs(shapes, cfg, mesh=MESH)
    assert tuple(specs["units"][0]["attn"]["wq"]) == (None, None, "model")
    assert tuple(specs["units"][0]["attn"]["wo"]) == (None, "model", None)
    assert tuple(specs["units"][0]["mlp"]["w_up"]) == (None, None, "model")
    assert all(a is None for a in tuple(specs["final_norm"]))


def test_param_specs_fsdp_adds_data_axis():
    cfg = get_smoke_config("grok_1_314b")
    from repro.models import transformer as T

    shapes = jax.eval_shape(lambda k: T.init_params(k, cfg), KEY)
    specs = sharding.param_specs(shapes, cfg, fsdp=True, mesh=None)
    assert tuple(specs["units"][0]["moe"]["w_up"]) == (None, None, "data", "model")


def test_sanitize_drops_nondividing_axes():
    # mamba vocab 50280 % 16 != 0 → model axis must be dropped
    spec = sharding.sanitize_spec(P("model", None), (50280, 1536), MESH)
    assert tuple(spec) == (None, None)
    spec = sharding.sanitize_spec(P("model", None), (50304, 1536), MESH)
    assert tuple(spec) == ("model", None)
    # tuple axes: batch 8 not divisible by pod*data=32 → dropped
    spec = sharding.sanitize_spec(P(("pod", "data"), None), (8, 4), MESH)
    assert tuple(spec) == (None, None)


def test_filter_spec_removes_missing_axes():
    single = make_abstract_mesh((16, 16), ("data", "model"))
    f = sharding.filter_spec(P(("pod", "data"), "model"), single)
    assert tuple(f) == ("data", "model")


def test_batch_and_cache_specs():
    cfg = get_smoke_config("gemma2_27b")
    from repro.models import transformer as T

    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    bs = sharding.batch_specs(batch, mesh=MESH)
    assert tuple(bs["tokens"]) == (("pod", "data"), None)
    caches = jax.eval_shape(lambda: T.init_caches(cfg, 128, 512))
    cs = sharding.cache_specs(caches, cfg, mesh=MESH)
    k_spec = tuple(cs["units"][0]["k"])
    assert k_spec[1] == ("pod", "data")


# ----------------------------------------------------------------- FT

def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(FTConfig(straggler_factor=2.0, straggler_patience=2))
    for t in range(20):
        det.report("h0", 1.0)
        det.report("h1", 1.0)
    assert det.report("h2", 5.0) is False  # patience 1
    assert det.report("h2", 5.0) is True  # patience 2 → confirmed


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(["a", "b"], timeout_s=10.0)
    hb.beat("a", now=100.0)
    hb.beat("b", now=100.0)
    assert hb.dead_hosts(now=105.0) == []
    assert hb.dead_hosts(now=111.0) == ["a", "b"]
    hb.beat("a", now=112.0)
    assert hb.dead_hosts(now=115.0) == ["b"]


def test_restart_policy_budget():
    pol = RestartPolicy(max_restarts=2, backoff_s=0.5)
    assert pol.on_failure(RuntimeError("x")) == 0.5
    assert pol.on_failure(RuntimeError("x")) == 1.0
    with pytest.raises(RuntimeError, match="budget exhausted"):
        pol.on_failure(RuntimeError("x"))


def test_fault_injector_fires_once():
    inj = FaultInjector({3})
    inj.maybe_fail(2)
    with pytest.raises(RuntimeError):
        inj.maybe_fail(3)
    inj.maybe_fail(3)  # second pass: already fired


def test_elastic_rescale_plan():
    plan = plan_rescale({"pod": 2, "data": 16, "model": 16},
                        {"data": 16, "model": 16}, global_batch=256)
    assert plan.per_device_batch_old == 8.0
    assert plan.per_device_batch_new == 16.0
    assert any("scale-down" in n for n in plan.notes)
