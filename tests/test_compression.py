"""Gradient-compression correctness (needs 8 fake devices → subprocess,
because the main pytest process must keep the real 1-device platform)."""

import os
import subprocess
import sys

import numpy as np

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import inspect
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.compression import compressed_psum_mean

try:  # jax >= 0.6 promotes shard_map to the top level
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map

# the replication-check kwarg was renamed check_rep -> check_vma
_ck = "check_vma" if "check_vma" in inspect.signature(shard_map).parameters else "check_rep"

mesh = jax.make_mesh((8,), ("data",))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 1000))
err = jnp.zeros((8, 1000))

@jax.jit
def run(g, err):
    f = shard_map(lambda gl, el: compressed_psum_mean(gl[0], el[0], "data"),
                  mesh=mesh, in_specs=(P("data", None), P("data", None)),
                  out_specs=(P(None), P("data")), **{_ck: False})
    return f(g, err)

mean, new_err = run(g, err)
true = g.mean(axis=0)
rel = float(jnp.abs(mean - true).max() / jnp.abs(true).max())
assert rel < 0.05, rel
# error feedback: residual equals what quantization dropped
assert float(jnp.abs(new_err).max()) > 0
print("REL", rel)
"""


def test_compressed_allreduce_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rel = float(out.stdout.strip().split()[-1])
    assert rel < 0.05
