"""Shared fixtures.  NOTE: the 512-device XLA flag is set ONLY inside
launch/dryrun.py — tests and benches must see the real (1-device) platform."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
