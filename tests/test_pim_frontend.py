"""repro.pim trace-and-compile frontend: fused multi-op programs bit-exact
vs the jnp per-op oracle on both bases and both executor backends, the
fused-MAC cost acceptance (fewer gates + fewer HBM planes than separate
dispatches), cache canonicalization, the new one-line public wrappers, and
the compress_schedule deprecation."""

import numpy as np
import pytest

import jax.numpy as jnp

try:  # hypothesis is optional: fall back to deterministic seeded cases
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hyp import given, settings, st

import repro.pim as pim
from repro.core import ir, machine, simulate
from repro.core.machine import PlaneVM

np.seterr(all="ignore")

N_VEC = 96

_MAC = lambda a, b, c: a * b + c  # noqa: E731
_CHAIN = lambda a, b, c: (a + b) * c + a  # noqa: E731 — 3 ops, reuses a


def _rand(dtype, rng):
    if dtype.kind == "fixed":
        lo, hi = -(2 ** (dtype.nbits - 1)), 2 ** (dtype.nbits - 1)
        return jnp.asarray(rng.integers(lo, hi, N_VEC).astype(np.int32))
    bits = rng.integers(0, 2**32, N_VEC, dtype=np.uint64).astype(np.uint32)
    if dtype.kind == "bf16":
        return jnp.asarray((bits >> 16).astype(np.uint16)).view(jnp.bfloat16)
    return jnp.asarray(bits.view(np.float32))


def _oracle(fn, dtype, args):
    """Per-op rounding/wrapping oracle: numpy ops on the carrier dtype for
    floats (numpy honors gradual underflow; XLA CPU flushes subnormal
    operands), masked int64 steps for fixed.  bf16 args arrive as ml_dtypes
    arrays via np.asarray, whose ufuncs round per-op."""
    if dtype.kind != "fixed":
        return fn(*(np.asarray(a) for a in args))

    n = dtype.nbits

    class W:  # wrapping int of width n, per-op truncation
        def __init__(self, v):
            m = np.int64(v) & ((1 << n) - 1)
            self.v = np.where(m >= 1 << (n - 1), m - (1 << n), m).astype(np.int64)

        def __add__(self, o):
            return W(self.v + o.v)

        def __mul__(self, o):
            return W(self.v * o.v)

    return jnp.asarray(fn(*(W(np.asarray(a)) for a in args)).v.astype(np.int32))


def _check(dtype, got, exp):
    if dtype.kind == "fixed":
        assert np.array_equal(np.asarray(got), np.asarray(exp))
        return
    width = np.uint16 if dtype.kind == "bf16" else np.uint32
    f = np.float32
    gb = np.asarray(got).view(width)
    eb = np.asarray(exp).view(width)
    nan = np.isnan(np.asarray(got, f)) & np.isnan(np.asarray(exp, f))
    ok = (gb == eb) | nan
    assert ok.all(), f"{(~ok).sum()} mismatches"


_DTYPES = {"f32": pim.f32, "bf16": pim.bf16, "int8": pim.int8, "int16": pim.int16}


@pytest.mark.parametrize("basis", ["memristive", "dram"])
@pytest.mark.parametrize("dtype", sorted(_DTYPES))
@pytest.mark.parametrize("prog", ["mac", "chain"])
def test_fused_programs_bit_exact_property(prog, dtype, basis):
    """Property test: fused MAC and the 3-op chain are bit-exact vs the
    per-op jnp oracle on both bases through the interpreter backend."""
    dt = _DTYPES[dtype]
    fn = _MAC if prog == "mac" else _CHAIN
    compiled = pim.compile(fn, dtype=dt, backend="interpreter")

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=3, deadline=None)
    def inner(seed):
        rng = np.random.default_rng(seed)
        args = [_rand(dt, rng) for _ in range(3)]
        got = compiled(*args, basis=basis)
        _check(dt, got, _oracle(fn, dt, args))

    inner()


@pytest.mark.parametrize("basis", ["memristive", "dram"])
@pytest.mark.parametrize("dtype", sorted(_DTYPES))
def test_fused_mac_pallas_matches_interpreter(dtype, basis):
    """The Pallas (interpret) backend executes the same fused CompiledSchedule
    as the interpreter, bit-for-bit, at every dtype on both bases."""
    dt = _DTYPES[dtype]
    mac = pim.compile(_MAC, dtype=dt)
    rng = np.random.default_rng(sum(map(ord, dtype + basis)))
    args = [_rand(dt, rng) for _ in range(3)]
    got_p = mac(*args, basis=basis, backend="pallas")
    got_i = mac(*args, basis=basis, backend="interpreter")
    _check(dt, got_p, got_i)
    _check(dt, got_p, _oracle(_MAC, dt, args))


# ------------------------------------------------------- cost acceptance


def test_fused_f32_mac_beats_separate_dispatches():
    """Acceptance: compile(a*b+c) reports strictly fewer total gates and
    strictly fewer HBM plane transfers than separate float_mul + float_add
    dispatches (cross-op CSE/fuse/DCE fire across the region boundary), and
    peak live columns stay within the paper's 1024 budget."""
    rep = pim.compile(_MAC, dtype=pim.f32).cost()
    sep = [ir.op_cost("float_mul"), ir.op_cost("float_add")]
    assert rep.gates < sum(r.gates for r in sep)
    assert rep.cycles < sum(r.cycles for r in sep)
    assert rep.hbm_planes < sum(r.hbm_planes for r in sep)
    assert rep.hbm_planes == 4 * 32  # 3 inputs + 1 output; no intermediates
    assert rep.num_cols <= 1024
    # recorded NORs also shrink: the shared record-mode VM dedups across ops
    assert rep.recorded_gates < sum(r.recorded_gates for r in sep)
    # the dram lowering of the same program still wins on data movement and
    # stays within a whisker on gates (pass-interaction noise, < 0.5%)
    repd = pim.compile(_MAC, dtype=pim.f32).cost(basis="dram")
    sepd = [ir.op_cost("float_mul", basis="dram"), ir.op_cost("float_add", basis="dram")]
    assert repd.hbm_planes < sum(r.hbm_planes for r in sepd)
    assert repd.gates <= 1.005 * sum(r.gates for r in sepd)
    assert repd.peak_rows <= 1024


@pytest.mark.parametrize("basis", ["memristive", "dram"])
def test_fused_int_mac_dce_across_boundary(basis):
    """The fused fixed-point MAC's int8 result type makes the high product
    half dead, so DCE deletes its gates — strictly fewer gates AND cycles
    than the full-width ``_OP_TABLE`` dispatches on both bases, and strictly
    fewer HBM planes than even truncated separate dispatches."""
    rep = pim.compile(_MAC, dtype=pim.int8).cost(basis=basis)
    sep_full = [ir.op_cost("fixed_mul", 8, basis=basis),
                ir.op_cost("fixed_add", 8, basis=basis)]
    assert rep.gates < sum(r.gates for r in sep_full)
    assert rep.cycles < sum(r.cycles for r in sep_full)
    # vs what the public wrappers dispatch (truncated mul): fusion's win is
    # the boundary traffic — the 8 product planes never leave the array
    sep_trunc = [pim.compile(lambda a, b: a * b, dtype=pim.int8).cost(basis=basis),
                 pim.compile(lambda a, b: a + b, dtype=pim.int8).cost(basis=basis)]
    assert rep.gates <= sum(r.gates for r in sep_trunc)
    assert rep.hbm_planes < sum(r.hbm_planes for r in sep_trunc)


def test_report_hbm_bytes():
    from repro.core.costmodel import MEMRISTIVE_PIM

    rep = pim.compile(_MAC, dtype=pim.f32).cost()
    # 128 boundary planes × 4096 elems / 8 bits per byte = 64 KiB
    assert MEMRISTIVE_PIM.report_hbm_bytes(rep, 4096) == 128 * 4096 / 8


def test_single_op_trace_canonicalizes_to_compile_op_cache():
    """pim.compile(lambda a, b: a + b) and ir.compile_op('float_add') share
    one cache entry — compile_op is the one-op special case."""
    add = pim.compile(lambda a, b: a + b, dtype=pim.f32)
    assert add.compiled() is ir.compile_op("float_add")
    assert add.compiled(basis="dram") is ir.compile_op("float_add", basis="dram")
    stats = ir.cache_stats()
    assert stats["hits"] >= 1 and stats["misses"] >= 1


def test_multi_output_program():
    fn = pim.compile(lambda a, b: (a + b, a * b), dtype=pim.int8,
                     backend="interpreter")
    rng = np.random.default_rng(3)
    x, y = (_rand(pim.int8, rng) for _ in range(2))
    s, p = fn(x, y)
    _check(pim.int8, s, _oracle(lambda a, b: a + b, pim.int8, (x, y)))
    _check(pim.int8, p, _oracle(lambda a, b: a * b, pim.int8, (x, y)))
    rep = fn.cost()
    assert rep.hbm_planes_out == 16  # two int8 outputs


def test_trace_errors():
    with pytest.raises(pim.TraceError):  # non-scalar constants stay errors
        pim.compile(lambda a, b: a + "one", dtype=pim.f32)
    with pytest.raises(pim.TraceError):  # non-integral constant in fixed
        pim.compile(lambda a, b: a + 1.5, dtype=pim.int8)
    with pytest.raises(pim.TraceError):
        pim.compile(lambda a, b: a + b, dtype=(pim.f32, pim.bf16))
    with pytest.raises(KeyError):  # no bf16 division netlist registered
        pim.compile(lambda a, b: a / b, dtype=pim.bf16)
    with pytest.raises(pim.TraceError):
        pim.compile(lambda a: 7, dtype=pim.f32)
    with pytest.raises(pim.TraceError):  # *args is not traceable
        pim.compile(lambda *args: args[0] + args[1], dtype=pim.f32)
    with pytest.raises(pim.TraceError, match="overflows"):  # 10**400 > f64
        pim.compile(lambda a: a + 10**400, dtype=pim.f32)
    with pytest.raises(ValueError, match="only applies to the pallas"):
        pim.compile(lambda a, b: a + b, dtype=pim.int8)(
            np.arange(3, dtype=np.int32), np.arange(3, dtype=np.int32),
            backend="interpreter", mode="unrolled")


# --------------------------------------------------- scalar constants


def test_scalar_constants_f32():
    """Python scalars trace to immediate INIT planes: bit-exact vs numpy
    (same rounding as runtime data) with no extra HBM input planes."""
    fn = pim.compile(lambda a, b: a * b + 2.5, dtype=pim.f32,
                     backend="interpreter")
    rng = np.random.default_rng(21)
    x = rng.standard_normal(N_VEC).astype(np.float32)
    y = rng.standard_normal(N_VEC).astype(np.float32)
    _check(pim.f32, fn(x, y), (x * y + np.float32(2.5)).astype(np.float32))
    rep = fn.cost()
    assert rep.hbm_planes_in == 64  # the constant is not an input plane


def test_scalar_constants_reverse_and_fixed():
    two_minus = pim.compile(lambda a: 2 - a, dtype=pim.int8,
                            backend="interpreter")
    x = np.array([5, -3, 127, -128, 0], np.int32)
    exp = ((2 - x + 128) % 256 - 128).astype(np.int32)
    assert np.array_equal(np.asarray(two_minus(x)), exp)

    scale = pim.compile(lambda a: a * 3 + 1, dtype=pim.int8,
                        backend="interpreter")
    exp2 = ((x * 3 + 1 + 128) % 256 - 128).astype(np.int32)
    assert np.array_equal(np.asarray(scale(x)), exp2)

    # negative constants wrap to the signed representative at every width,
    # including the full-int32 case whose raw mask overflows the carrier
    neg32 = pim.compile(lambda a: a + (-5), dtype=pim.int32,
                        backend="interpreter")
    xw = np.array([100, -100, 2**31 - 1], np.int32)
    expw = (((xw.astype(np.int64) - 5) + 2**31) % 2**32 - 2**31).astype(np.int32)
    assert np.array_equal(np.asarray(neg32(xw)), expw)


def test_scalar_constants_fold_and_dedup():
    """Repeated constants trace to one node; constant folding then chews
    through the INIT planes, so `a * 1.0` costs no more gates than `a + 0.0`
    costs planes — and the program key distinguishes different immediates."""
    f1 = pim.compile(lambda a, b: a * 2.0 + b * 2.0, dtype=pim.f32)
    consts = [n for n in f1.program.body if n.op == ir.CONST_OP]
    assert len(consts) == 1  # deduplicated per bit pattern
    k2 = pim.compile(lambda a, b: a * 2.0 + b * 4.0, dtype=pim.f32)
    assert f1.program.key != k2.program.key

    # big integer constants in float traces round like floats (2**35 would
    # overflow the fixed-point carrier path)
    big = pim.compile(lambda a: a + 2**35, dtype=pim.f32,
                      backend="interpreter")
    xb = np.array([1.0, -(2.0**35)], np.float32)
    _check(pim.f32, big(xb), (xb + np.float32(2**35)).astype(np.float32))

    # constant dedup is per dtype: int16 16256 and bf16 1.0 share a bit
    # pattern but must not share a tracer in a multi-dtype trace
    mixed = pim.compile(lambda a, b: (a + 16256, b + 1.0),
                        dtype=(pim.int16, pim.bf16), backend="interpreter")
    xi = np.array([1, -2], np.int32)
    xf = np.array([0.5, -3.0], np.float32)
    s, f = mixed(xi, jnp.asarray(xf, jnp.bfloat16))
    exp_i = (((xi + 16256) + 2**15) % 2**16 - 2**15).astype(np.int32)
    assert np.array_equal(np.asarray(s), exp_i)
    import ml_dtypes
    _check(pim.bf16, f, (xf.astype(np.float64) + 1.0).astype(ml_dtypes.bfloat16))


def test_simulate_float_mac_oracle_and_cost():
    rng = np.random.default_rng(5)
    x, y, c = (rng.standard_normal(64).astype(np.float32) for _ in range(3))
    got, rep = simulate.float_mac(x, y, c)
    exp = (x * y + c).astype(np.float32)
    _check(pim.f32, got, exp)
    assert rep.hbm_planes == 128
    assert rep.gates == pim.compile(_MAC, dtype=pim.f32).cost().gates


# ------------------------------------------------- new one-line wrappers


def test_new_public_wrappers_bit_exact():
    from repro.kernels import ops

    rng = np.random.default_rng(11)
    xi = rng.integers(-128, 128, 200).astype(np.int32)
    yi = rng.integers(-128, 128, 200).astype(np.int32)
    yi[yi == 0] = 1
    got = np.asarray(ops.pim_fixed_sub(xi, yi, nbits=8))
    exp = ((xi - yi) & 0xFF)
    exp = np.where(exp >= 128, exp - 256, exp).astype(np.int32)
    assert np.array_equal(got, exp)

    got = np.asarray(ops.pim_fixed_div(xi, yi, nbits=8))
    exp = np.trunc(xi / yi).astype(np.int64) & 0xFF
    exp = np.where(exp >= 128, exp - 256, exp).astype(np.int32)
    assert np.array_equal(got, exp)

    xf = rng.standard_normal(128).astype(np.float32)
    yf = rng.standard_normal(128).astype(np.float32)
    got = np.asarray(ops.pim_float_sub(xf, yf))
    _check(pim.f32, got, (xf - yf).astype(np.float32))
    got = np.asarray(ops.pim_float_div(xf, yf))
    _check(pim.f32, got, (xf / yf).astype(np.float32))


# --------------------------------------------------- deprecation (satellite)


def test_compress_schedule_deprecation_warns():
    """machine.compress_schedule survives only as a deprecated wrapper and
    must warn; its result still matches ir.lower directly."""
    vm = PlaneVM(mode="record")
    a, b = vm.input_plane(), vm.input_plane()
    out = vm.nor(a, b)
    sched = vm.finish_schedule({"a": [a], "b": [b]}, {"out": [out]})
    with pytest.warns(DeprecationWarning, match="compress_schedule"):
        compressed = machine.compress_schedule(sched)
    direct = ir.lower(ir.from_schedule(sched)).to_schedule()
    assert np.array_equal(compressed.ops, direct.ops)
    assert compressed.num_cols == direct.num_cols
