"""Cost-model calibration vs the paper's own numbers + roofline machinery."""

import numpy as np
import pytest

from repro.core import metrics
from repro.core.analyzer import Workload, analyze
from repro.core.costmodel import (
    A6000,
    DRAM_PIM,
    MEMRISTIVE_PIM,
    PAPER_GATE_COUNTS,
    PAPER_PIM_THROUGHPUT,
    TPU_V5E,
)
from repro.core.roofline import analyze_hlo, build_report, parse_collectives


def test_paper_fig3_throughput_reproduction():
    """All 8 Fig-3 PIM data points within 15% (the paper reports the small
    DRAM numbers to 1 significant digit: 0.0174 → "0.02")."""
    for (tech, op), target in PAPER_PIM_THROUGHPUT.items():
        cfg = MEMRISTIVE_PIM if tech == "memristive" else DRAM_PIM
        got = cfg.op_throughput(PAPER_GATE_COUNTS[op])
        assert abs(got - target) / target < 0.15, (tech, op, got, target)


def test_paper_table1_power():
    assert abs(MEMRISTIVE_PIM.max_power_w - 860) / 860 < 0.01
    assert abs(DRAM_PIM.max_power_w - 80) / 80 < 0.03


def test_gpu_membound_matches_measured():
    """Paper: experimental GPU ≈ 94% of bandwidth bound (0.057 vs 0.064 TOPS)."""
    bound = A6000.membound_throughput(12)  # 32-bit op: 2 reads + 1 write
    assert 0.85 * bound <= 0.057e12 <= bound


def test_fig4_inverse_relation():
    pts = metrics.fig4_points(MEMRISTIVE_PIM, A6000, PAPER_GATE_COUNTS)
    pts = sorted(pts, key=lambda p: p.cc)
    imps = [p.improvement for p in pts]
    assert imps == sorted(imps, reverse=True)  # higher CC → lower improvement


def test_analyzer_quadrants_match_paper_conclusion():
    # §6: training (high CC × high reuse) loses; decode (low reuse) wins
    train = Workload("train", flops=1e18, hbm_bytes=1e15)
    decode = Workload("decode", flops=2e9, hbm_bytes=2e9)
    assert not analyze(train).pim_wins
    assert analyze(decode).pim_wins
    assert analyze(train).quadrant.endswith("high-reuse")
    assert analyze(decode).quadrant.endswith("low-reuse")


def test_machine_balance_v5e():
    assert 200 < metrics.machine_balance(TPU_V5E) < 280


SAMPLE_HLO = """\
HloModule test

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %x = f32[128,256] get-tuple-element(%p), index=1
  %w = f32[256,256] constant(0)
  %y = f32[128,256] dot(f32[128,256] %x, f32[256,256] %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,256] all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%sum
  %i2 = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[128,256]) tuple(%i2, %ar)
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256] parameter(0)
  %init = (s32[], f32[128,256]) tuple(s32[] constant(0), %a)
  %w2 = (s32[], f32[128,256]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[128,256] get-tuple-element(%w2), index=1
}
"""


def test_hlo_walker_trip_counts_and_collectives():
    a = analyze_hlo(SAMPLE_HLO, default_group=4)
    # dot: 2*128*256*256 flops × 10 trips
    assert a.dot_flops == pytest.approx(10 * 2 * 128 * 256 * 256)
    # all-reduce: 128*256*4 B operand × ring 2·3/4 × 10 trips
    assert a.collectives.wire_bytes == pytest.approx(10 * 128 * 256 * 4 * 1.5)
    assert a.collectives.count == 10


def test_parse_collectives_simple():
    stats = parse_collectives(SAMPLE_HLO, default_group=4)
    assert stats.count == 1  # flat parse counts the loop body once
    assert stats.operand_bytes == pytest.approx(128 * 256 * 4)


def test_roofline_report_dominance():
    r = build_report(
        cell="t", chips=256, flops_per_device=1e12, hbm_bytes_per_device=1e9,
        hlo_text=SAMPLE_HLO, model_flops=2.56e14, use_fused_bytes=False,
    )
    assert r.dominant == "compute"
    assert 0 < r.roofline_fraction <= 1.0 + 1e-6
