"""Per-arch smoke tests: reduced same-family config, one forward/train step
on CPU, output shapes + finite values; decode parity against full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import transformer as T
from repro.models import cnn

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["cross_embeds"] = jax.random.normal(
            KEY, (B, cfg.cross_kv_len, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(KEY, cfg)
    batch = _batch(cfg)
    logits, aux, _ = T.forward(
        params, batch["tokens"], cfg, cross_embeds=batch.get("cross_embeds")
    )
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, metrics = T.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: T.loss_fn(p, batch, cfg)[0])(params)
    gsum = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0


@pytest.mark.parametrize("arch", ["stablelm_3b", "gemma2_27b", "mamba2_780m",
                                  "recurrentgemma_9b", "deepseek_moe_16b"])
def test_arch_decode_parity(arch):
    """prefill+decode must agree with the full forward at the last position."""
    import dataclasses

    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        # capacity-dropping differs between prefill(T-1) and forward(T) token
        # counts; parity requires drop-free routing
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_params(KEY, cfg)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    caches = T.init_caches(cfg, B, S)
    _, caches = T.prefill(params, toks[:, : S - 1], cfg, caches)
    got, _ = T.decode_step(params, toks[:, -1:], jnp.asarray(S - 1), cfg, caches)
    full, _, _ = T.forward(params, toks, cfg)
    err = float(jnp.max(jnp.abs(got - full[:, -1])))
    # bf16 flash-vs-decode path tolerance; ssm is exact (fp32 state)
    assert err < 0.35, err


def test_unrolled_matches_scanned():
    import dataclasses

    cfg = get_smoke_config("gemma2_27b")
    params = T.init_params(KEY, cfg)
    batch = _batch(cfg)
    l1, _, _ = T.forward(params, batch["tokens"], cfg)
    cfg_u = dataclasses.replace(cfg, unroll_layers=True)
    l2, _, _ = T.forward(params, batch["tokens"], cfg_u)
    np.testing.assert_allclose(
        np.asarray(l1, np.float32), np.asarray(l2, np.float32), atol=1e-2, rtol=1e-2
    )


def test_moe_aux_and_capacity():
    from repro.models import moe as moe_mod

    cfg = get_smoke_config("deepseek_moe_16b")
    p = moe_mod.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.bfloat16)
    out, aux = moe_mod.apply_moe(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux["load_balance_loss"]) > 0
    assert 0.0 <= float(aux["dropped_frac"]) <= 1.0


def test_local_window_masks_long_range():
    """A token beyond the local window must not influence attention output."""
    from repro.models.attention import flash_attention

    B, T, H, D = 1, 8, 2, 16
    k = jax.random.normal(KEY, (B, T, H, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, D), jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, D), jnp.float32)
    out1 = flash_attention(q, k, v, causal=True, window=3)
    # perturb a key/value far outside the window of the last query
    k2 = k.at[:, 0].add(100.0)
    v2 = v.at[:, 0].add(100.0)
    out2 = flash_attention(q, k2, v2, causal=True, window=3)
    np.testing.assert_allclose(out1[:, -1], out2[:, -1], atol=1e-5)
    # ...but it must influence the full-attention result
    out3 = flash_attention(q, k2, v2, causal=True, window=0)
    assert float(jnp.abs(out3[:, -1] - out1[:, -1]).max()) > 1e-3


def test_flash_attention_matches_naive():
    B, T, H, D = 2, 24, 4, 16
    q = jax.random.normal(KEY, (B, T, H, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, D), jnp.float32)
    from repro.models.attention import flash_attention

    out = flash_attention(q, k, v, causal=True, bq=8, bk=8)
    # naive reference
    s = jnp.einsum("bthd,bshd->bhts", q, k) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((T, T), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    exp = jnp.einsum("bhts,bshd->bthd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("name", ["alexnet", "googlenet", "resnet50"])
def test_cnn_forward(name):
    init, apply = cnn.MODELS[name]
    p = init(jax.random.PRNGKey(0))
    out = apply(p, jnp.zeros((2, 224, 224, 3)))
    assert out.shape == (2, 1000)
    assert bool(jnp.isfinite(out).all())


def test_pad_heads_numerics_exact():
    """pad_heads_to with kv-group-aware grafting is numerically exact."""
    import dataclasses

    cfg = get_smoke_config("llama3_2_3b")  # 6 heads, kv=2, G=3
    params = T.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)
    base, _, _ = T.forward(params, toks, cfg)

    cfg_p = dataclasses.replace(cfg, pad_heads_to=8, opt_attn_layout=True)
    params_p = T.init_params(KEY, cfg_p)
    hd, K = cfg.hd, cfg.n_kv_heads
    G_old, G_new = cfg.n_heads // K, 8 // K

    def slot(i):
        return (i // G_old) * G_new + (i % G_old)

    for u_p, u_o in zip(params_p["units"], params["units"]):
        wq = jnp.zeros_like(u_p["attn"]["wq"])
        wo = jnp.zeros_like(u_p["attn"]["wo"])
        for i in range(cfg.n_heads):
            s_ = slot(i)
            wq = wq.at[:, :, s_ * hd:(s_ + 1) * hd].set(u_o["attn"]["wq"][:, :, i * hd:(i + 1) * hd])
            wo = wo.at[:, s_ * hd:(s_ + 1) * hd, :].set(u_o["attn"]["wo"][:, i * hd:(i + 1) * hd, :])
        u_p["attn"]["wq"] = wq
        u_p["attn"]["wo"] = wo
        u_p["attn"]["wk"] = u_o["attn"]["wk"]
        u_p["attn"]["wv"] = u_o["attn"]["wv"]
        u_p["attn_norm"] = u_o["attn_norm"]
        u_p["mlp_norm"] = u_o["mlp_norm"]
        u_p["mlp"] = u_o["mlp"]
    params_p["embed"] = params["embed"]
    params_p["final_norm"] = params["final_norm"]
    params_p["tail"] = params["tail"]

    out, _, _ = T.forward(params_p, toks, cfg_p)
    assert float(jnp.max(jnp.abs(out - base))) == 0.0


def test_kv_quant_decode_parity():
    """int8 KV cache decode stays within quantization tolerance."""
    import dataclasses

    cfg = dataclasses.replace(get_smoke_config("musicgen_large"), opt_kv_quant=True)
    params = T.init_params(KEY, cfg)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    caches = T.init_caches(cfg, B, S)
    assert caches["units"][0]["k"].dtype == jnp.int8
    _, caches = T.prefill(params, toks[:, : S - 1], cfg, caches)
    got, _ = T.decode_step(params, toks[:, -1:], jnp.asarray(S - 1), cfg, caches)
    full, _, _ = T.forward(params, toks, cfg)
    assert float(jnp.max(jnp.abs(got - full[:, -1]))) < 0.6


def test_flash_remat_matches_forward():
    """opt_flash_remat changes the backward schedule, not the function."""
    import dataclasses

    cfg = get_smoke_config("qwen2_5_14b")
    params = T.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    l1, _ = T.loss_fn(params, batch, cfg)
    cfg_r = dataclasses.replace(cfg, opt_flash_remat=True)
    l2, _ = T.loss_fn(params, batch, cfg_r)
    assert abs(float(l1) - float(l2)) < 1e-3
    g1 = jax.grad(lambda p: T.loss_fn(p, batch, cfg)[0])(params)
    g2 = jax.grad(lambda p: T.loss_fn(p, batch, cfg_r)[0])(params)
    d = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    assert d < 1e-2, d
