"""Batched serving example: prefill + decode with the gemma2-family smoke
model, plus the PIM-offload verdict for the decode phase — the paper's §6
observation (memory-bound decode is PIM territory) demonstrated live.

  PYTHONPATH=src python examples/serve_decode.py
"""

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.analyzer import Workload, analyze
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import ServeEngine


def main():
    cfg = get_smoke_config("gemma2_27b")
    engine = ServeEngine.build(cfg, make_host_mesh(), max_seq=48)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (4, 16)).astype(np.int32)
    out = engine.generate(prompts, 24, temperature=0.8)
    print(f"[serve] generated {out.shape[0]} sequences × {out.shape[1]} tokens")
    for row in out[:2]:
        print("  ", row[-24:].tolist())

    # the paper's Fig-8 verdict for the FULL gemma2-27b decode step
    full = get_config("gemma2_27b")
    n = full.param_count()
    w = Workload(
        "gemma2-27b decode bs=128", flops=2 * n * 128, hbm_bytes=2 * n + 128 * 2e6
    )
    v = analyze(w)
    print(f"[analyzer] {w.name}: reuse={v.reuse:.1f} FLOP/B, {v.quadrant}, "
          f"PIM {'WINS' if v.pim_wins else 'loses'} ({v.speedup:.2g}×)")


if __name__ == "__main__":
    main()
