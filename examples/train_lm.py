"""End-to-end training driver: a ~100M-parameter llama-family model on the
synthetic stream, with checkpointing and fault-tolerant restart.

Full run (a few hundred steps, ~100M params):
  PYTHONPATH=src python examples/train_lm.py --d-model 512 --layers 12 \
      --steps 300 --batch 8 --seq 256

Quick CI-scale run:
  PYTHONPATH=src python examples/train_lm.py --steps 20
"""

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.launch.mesh import make_host_mesh
from repro.launch.train import build_run, train_loop
from repro.runtime.fault_tolerance import FTConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    base = get_smoke_config("llama3_2_3b")
    cfg = dataclasses.replace(
        base, name="train-lm-example", n_layers=args.layers, d_model=args.d_model,
        n_heads=max(4, args.d_model // 64), n_kv_heads=max(2, args.d_model // 128),
        d_ff=args.d_model * 4, vocab=args.vocab,
    )
    mesh = make_host_mesh()
    run = build_run(cfg, mesh, optimizer_name="adamw-fast")
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(run.params))
    print(f"[example] {cfg.name}: {n/1e6:.1f}M params, {args.steps} steps")
    stream = SyntheticStream(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, structure=0.85
    ))
    run, hist = train_loop(
        run, stream, args.steps, ckpt_dir=args.ckpt_dir,
        ft=FTConfig(checkpoint_every=50), log_every=10,
    )
    losses = [h["loss"] for h in hist]
    print(f"[example] loss: {losses[0]:.3f} → {losses[-1]:.3f} "
          f"(mean step {np.mean([h['time_s'] for h in hist])*1e3:.0f} ms)")
    if args.steps >= 50:  # too noisy to assert on shorter runs
        assert np.mean(losses[-10:]) < np.mean(losses[:10]), "training must make progress"


if __name__ == "__main__":
    main()
