"""Quickstart: the paper's core loop in 60 lines.

1. Compile and run a fused element-wise PIM program (`repro.pim` frontend).
2. Price it on both logic bases and against separate dispatches.
3. Ask the Fig-8 analyzer where a workload should run.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import repro.pim as pim
from repro.core import ir
from repro.core.analyzer import Workload, analyze
from repro.core.costmodel import DRAM_PIM, MEMRISTIVE_PIM

# --- 1. trace-and-compile a fused MAC: one in-memory schedule, the
#        intermediate product planes never round-trip through HBM
rng = np.random.default_rng(0)
x = rng.standard_normal(1024).astype(np.float32)
y = rng.standard_normal(1024).astype(np.float32)
c = rng.standard_normal(1024).astype(np.float32)

mac = pim.compile(lambda a, b, z: a * b + z, dtype=pim.f32)
out = mac(x, y, c)  # Pallas executor (interpret mode on CPU), bit-exact
exp = (x * y + c).astype(np.float32)
assert (np.asarray(out).view(np.uint32) == exp.view(np.uint32)).all()
print(f"fused f32 MAC: bit-exact over {x.size} lanes")

# --- 2. program-level cost vs separate dispatches, on both bases
sep = [ir.op_cost("float_mul"), ir.op_cost("float_add")]
for basis, cfg in (("memristive", MEMRISTIVE_PIM), ("dram", DRAM_PIM)):
    rep = mac.cost(basis=basis)
    print(f"{basis:11s} MAC: {rep.gates} gates, {rep.cycles} cycles, "
          f"peak {rep.peak_rows or rep.num_cols} rows, "
          f"{cfg.report_throughput(rep)/1e12:.3f} TMAC/s")
print(f"HBM planes/dispatch: fused {mac.cost().hbm_planes} vs "
      f"separate mul+add {sum(r.hbm_planes for r in sep)} — "
      "the in-memory advantage the paper's Fig 3/8 story is about")

# int8 MAC: the program's int8 result type means DCE deletes the dead high
# product half that a full-width 2n-bit fixed_mul dispatch must compute
mac8 = pim.compile(lambda a, b, z: a * b + z, dtype=pim.int8)
sep8 = sum(ir.op_cost(o, 8).gates for o in ("fixed_mul", "fixed_add"))
print(f"int8 MAC gates: fused {mac8.cost().gates} vs full-width dispatches {sep8}; "
      f"HBM planes {mac8.cost().hbm_planes} vs 48")

# --- 3. offload decision (paper Fig 8): CC × reuse quadrants
decode = Workload("llm-decode bs=1 (3B params)", flops=2 * 3e9, hbm_bytes=2 * 3e9)
train = Workload("llm-train 1M tokens (3B)", flops=6 * 3e9 * 1e6, hbm_bytes=60e9)
for w in (decode, train):
    v = analyze(w)
    print(f"{w.name:28s} reuse={v.reuse:9.1f} {v.quadrant:22s} "
          f"PIM {'WINS' if v.pim_wins else 'loses'} ({v.speedup:.2g}x)")
