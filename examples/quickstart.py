"""Quickstart: the paper's core loop in 60 lines.

1. Run bit-exact digital-PIM arithmetic (AritPIM suite) on vectors.
2. Price the same ops on the paper's PIM configs and on GPU/TPU rooflines.
3. Ask the Fig-8 analyzer where a workload should run.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import simulate
from repro.core.analyzer import Workload, analyze
from repro.core.costmodel import DRAM_PIM, MEMRISTIVE_PIM, PAPER_GATE_COUNTS

# --- 1. bit-exact in-memory arithmetic (element-parallel across rows)
rng = np.random.default_rng(0)
x = rng.standard_normal(1024).astype(np.float32)
y = rng.standard_normal(1024).astype(np.float32)

z, cost = simulate.float_add(x, y)
assert (np.asarray(z).view(np.uint32) == (x + y).view(np.uint32)).all()
print(f"float32 add: bit-exact over {x.size} lanes; "
      f"{cost.gates} NOR gates/element, CC={cost.compute_complexity:.1f}")

# --- 2. the analytical cost model (calibrated to the paper's Fig 3)
for tech, cfg in (("memristive", MEMRISTIVE_PIM), ("dram", DRAM_PIM)):
    tput = cfg.op_throughput(PAPER_GATE_COUNTS["float32_add"])
    print(f"{tech:11s} float32 add: {tput/1e12:6.2f} TOPS "
          f"@ {cfg.max_power_w:.0f} W  ({cfg.num_crossbars} crossbars)")

# --- 3. offload decision (paper Fig 8): CC × reuse quadrants
decode = Workload("llm-decode bs=1 (3B params)", flops=2 * 3e9, hbm_bytes=2 * 3e9)
train = Workload("llm-train 1M tokens (3B)", flops=6 * 3e9 * 1e6, hbm_bytes=60e9)
for w in (decode, train):
    v = analyze(w)
    print(f"{w.name:28s} reuse={v.reuse:9.1f} {v.quadrant:22s} "
          f"PIM {'WINS' if v.pim_wins else 'loses'} ({v.speedup:.2g}x)")
