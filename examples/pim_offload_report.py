"""Beyond-paper showcase: the ConvPIM Fig-8 criterion applied to every
dry-run cell of the 10 assigned 2026-era LM architectures.

Reads results/dryrun_baseline/*.json (produced by repro.launch.dryrun) and
prints, per (arch × shape), the CC/reuse quadrant and whether the modeled
digital PIM beats the TPU-pod roofline — reproducing the paper's conclusion
(training loses, memory-bound decode wins) on modern workloads.

  PYTHONPATH=src python examples/pim_offload_report.py [results_dir]
"""

import glob
import json
import os
import sys

from repro.core.analyzer import Workload, analyze


def main():
    directory = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline"
    records = []
    for p in sorted(glob.glob(os.path.join(directory, "*__16x16.json"))):
        with open(p) as f:
            records.append(json.load(f))
    if not records:
        print(f"no dry-run records in {directory}; run repro.launch.dryrun first")
        return
    print(f"{'cell':44s} {'reuse':>9s} {'quadrant':22s} {'PIM?':5s} {'speedup':>8s}")
    wins = 0
    for r in records:
        w = Workload(
            f'{r["arch"]}×{r["shape"]}',
            flops=r["flops_per_device"] * r["chips"],
            hbm_bytes=r["fused_bytes_per_device"] * r["chips"],
        )
        v = analyze(w, chips=r["chips"])
        wins += v.pim_wins
        print(f"{w.name:44s} {v.reuse:9.1f} {v.quadrant:22s} "
              f"{'WIN' if v.pim_wins else '-':5s} {v.speedup:8.2g}")
    print(f"\nPIM wins {wins}/{len(records)} cells — paper §6 predicts wins only in "
          "the low-reuse (decode) rows.")


if __name__ == "__main__":
    main()
