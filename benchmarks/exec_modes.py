"""Executor-mode shootout: wave-scheduled straight-line vs fori_loop.

Races the ``pallas-unrolled`` kernel against the ``pallas-loop`` kernel (and
the ``interpreter`` scan oracle) on fused MAC programs, reporting per mode
the schedule shape the compiler produced — gates, peak columns after the
``reorder`` pass, dependency waves (``parallel_cycles``) — next to measured
wall time per dispatch.  This is the CI perf gate: ``benchmarks/smoke.py``
fails if the unrolled kernel is not faster than the loop kernel on the f32
fused MAC, and ``benchmarks/run.py --json BENCH_exec.json`` emits the rows
as JSON so the perf trajectory is trackable across commits.

The first unrolled dispatch pays the straight-line XLA compile (tens of
seconds for the 13k-gate f32 MAC — the schedule splits into
``UNROLL_SEGMENT_GATES`` kernels); ``us_per_call`` times the steady state,
which is what a benchmarking sweep runs thousands of times.

Measurement caveat, CPU interpret mode: ``pallas-loop`` runs under
``pallas_call``'s interpret emulation while the unrolled body runs as a
plain jit (DESIGN.md §5).  The ``interpreter`` row is the emulation-free
loop baseline — a plain ``lax.scan`` of the same per-gate dispatch — and
lands within a few percent of ``pallas-loop``, so the unrolled win is the
straight-line kernel structure (no dynamic indexing / opcode select), not
the emulation layer.  Hardware (interpret=False) numbers are a separate
exercise on a real TPU.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

import repro.pim as pim
from repro.core import ir

from .common import run_cli, time_fn

N_ELEMS = 4096

_MODES = ("interpreter", "pallas-loop", "pallas-unrolled")

# dtype rows: f32 is the CI-gated case; int8 shows the auto threshold
# picking `unrolled` on its own.
_CASES = {"f32_mac": pim.f32, "int8_mac": pim.int8}


def _planes(mac, dtype, rng):
    if dtype.kind == "fixed":
        lo, hi = -(2 ** (dtype.nbits - 1)), 2 ** (dtype.nbits - 1)
        arrays = [jnp.asarray(rng.integers(lo, hi, N_ELEMS).astype(np.int32))
                  for _ in range(3)]
    else:
        arrays = [jnp.asarray(rng.standard_normal(N_ELEMS).astype(np.float32))
                  for _ in range(3)]
    return jnp.stack([p for t, x in zip(mac.in_types, arrays)
                      for p in t.to_planes(t.cast(x))])


def run(bases: tuple[str, ...] = ("memristive",),
        passes: tuple[str, ...] | None = None) -> list[dict]:
    from repro.kernels import pim_bitserial

    passes = ir.DEFAULT_PASSES if passes is None else passes
    rng = np.random.default_rng(0)
    rows = []
    for name, dtype in _CASES.items():
        mac = pim.compile(lambda a, b, c: a * b + c, dtype=dtype)
        # Time the executor dispatch alone, on pre-packed planes — plane
        # pack/unpack is shared by every mode and would otherwise drown the
        # kernel difference.
        planes = _planes(mac, dtype, rng)
        for basis in bases:
            compiled = mac.compiled(basis=basis, passes=passes)
            for mode in _MODES:
                backend = ir.get_backend(mode)
                us = time_fn(
                    lambda backend=backend, c=compiled:
                        backend.run(c, planes).planes,
                    warmup=1, iters=3)
                rows.append({
                    "name": f"exec/{name}/{basis}/{mode}",
                    "us_per_call": f"{us:.0f}",
                    "gates": compiled.num_gates,
                    "num_cols": compiled.num_cols,
                    "waves": compiled.num_waves,
                    "auto_mode": pim_bitserial.resolve_mode(compiled),
                })
    return rows


def main():
    run_cli(run)


if __name__ == "__main__":
    main()
