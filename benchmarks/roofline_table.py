"""§Roofline table: collect dry-run records into the per-cell three-term
table + the PIM-offload (Fig 8) verdict for every cell."""

from __future__ import annotations

import glob
import json
import os

from repro.core.analyzer import Workload, analyze

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun_baseline")


def load_records(directory: str = RESULTS) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _compute_term(r: dict) -> float:
    """Per-cell compute seconds (EXPERIMENTS.md §Dry-run methodology):
    min(cost_analysis, walker) for dense; analytic (ideal × remat × capacity)
    for MoE, whose routing cumsum inflates cost_analysis."""
    ideal = r["model_flops"] / (r["chips"] * 197e12)
    if "moe" in r["arch"] or "grok" in r["arch"]:
        return ideal * (1.33 * 1.25 if "train" in r["shape"] else 1.25)
    return min(r["flops_per_device"] / 197e12, r["compute_s"])


def run() -> list[dict]:
    rows = []
    for r in load_records():
        if r["mesh"] != "16x16":
            continue  # the roofline table is single-pod (exact unrolled accounting)
        w = Workload(
            f'{r["arch"]}×{r["shape"]}',
            flops=max(r["flops_per_device"], 1.0) * r["chips"],
            hbm_bytes=max(r["fused_bytes_per_device"], 1.0) * r["chips"],
            collective_wire_bytes=r["collective_wire_bytes_per_dev"],
        )
        v = analyze(w, chips=r["chips"])
        comp = _compute_term(r)
        bound = max(comp, r["memory_s"], r["collective_s"])
        dom = "compute" if bound == comp else ("memory" if bound == r["memory_s"] else "collective")
        ideal = r["model_flops"] / (r["chips"] * 197e12)
        rows.append({
            "name": f'roofline/{r["arch"]}__{r["shape"]}',
            "us_per_call": "",
            "compute_ms": f'{comp*1e3:.2f}',
            "memory_ms": f'{r["memory_s"]*1e3:.2f}',
            "collective_ms": f'{r["collective_s"]*1e3:.2f}',
            "dominant": dom,
            "mfu_at_bound": f'{ideal/bound:.1%}',
            "fits_hbm": str(r.get("residency", {}).get("fits_16gb_hbm", "?")),
            "pim_offload_quadrant": v.quadrant,
            "pim_wins": str(v.pim_wins),
        })
    if not rows:
        rows.append({"name": "roofline/none", "us_per_call": "",
                     "note": f"no records in {RESULTS}; run launch.dryrun first"})
    return rows


def main():
    from .common import emit

    emit(run())


if __name__ == "__main__":
    main()
