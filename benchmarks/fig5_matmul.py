"""Paper Fig 5: batched n×n fp32 matrix multiplication — PIM vs accelerator,
as data reuse O(n) grows.

Reproduces the paper's crossover: for small n the accelerator is
memory-bound and PIM competes; by n≈128 reuse lifts the accelerator to
compute-bound and PIM loses (paper §4).  The us_per_call column times our
MatPIM-schedule Pallas kernel (interpret mode) on a small instance.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import A6000, DRAM_PIM, MEMRISTIVE_PIM, PAPER_GATE_COUNTS, TPU_V5E
from repro.kernels import ops

from .common import BASES, run_cli, time_fn

SIZES = (16, 32, 64, 128, 256, 512)


def pim_matmul_time(n: int, pim=MEMRISTIVE_PIM, gates=PAPER_GATE_COUNTS,
                    mac_cycles: int | None = None) -> float:
    """MatPIM: n² dot products of length n per matrix pair, bit-serial
    element-parallel → per-pair work = n³ MACs; rows hold matrix pairs.

    ``mac_cycles`` prices one MAC from a compiled program (e.g. the fused
    ``a*b+c`` schedule on the config's own basis); the default is the
    paper-calibrated gates × cycles_per_gate convention."""
    macs = n**3
    if mac_cycles is None:
        mac_cycles = (gates["float32_add"] + gates["float32_mul"]) * pim.cycles_per_gate
    # one pair occupies n rows (row-parallel rank-1 updates over n steps)
    pairs_parallel = pim.total_rows / n
    cycles = macs / n * mac_cycles  # n-way row parallel per pair
    return cycles / pim.clock_hz / pairs_parallel  # seconds per pair at full occupancy


def run(bases: tuple[str, ...] = BASES,
        passes: tuple[str, ...] | None = None) -> list[dict]:
    from repro.core import ir
    from repro.core.simulate import mac_cost

    rows = []
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(2, 128, 128)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(2, 128, 128)), jnp.float32)
    kernel_us = time_fn(lambda x, y: ops.pim_matmul_op(x, y), a, b, warmup=1, iters=2)
    passes = ir.DEFAULT_PASSES if passes is None else passes

    for n in SIZES:
        flops = 2 * n**3
        bytes_ = 3 * n * n * 4
        t_pim = pim_matmul_time(n)
        t_gpu_mem = bytes_ / A6000.mem_bw
        t_gpu_comp = flops / A6000.peak_fp32
        t_tpu_mem = bytes_ / TPU_V5E.hbm_bw
        t_tpu_comp = flops / TPU_V5E.peak_bf16
        pim_tput = 1.0 / t_pim
        row = {
            "name": f"fig5/matmul_n{n}",
            "us_per_call": f"{kernel_us:.0f}" if n == 128 else "",
            "reuse_flops_per_byte": f"{flops/bytes_:.1f}",
            "pim_pairs_per_s": f"{pim_tput:.3g}",
        }
        # per-basis columns from the fused-MAC compiled schedule (one
        # compile per basis, then cached)
        for basis, cfg in (("memristive", MEMRISTIVE_PIM), ("dram", DRAM_PIM)):
            if basis not in bases:
                continue
            t = pim_matmul_time(
                n, cfg, mac_cycles=mac_cost(basis=basis, passes=passes).cycles)
            row[f"{basis}_fusedmac_pairs_per_s"] = f"{1/t:.3g}"
        row.update({
            "gpu_membound_pairs_per_s": f"{1/t_gpu_mem:.3g}",
            "gpu_computebound_pairs_per_s": f"{1/t_gpu_comp:.3g}",
            "tpu_membound_pairs_per_s": f"{1/t_tpu_mem:.3g}",
            "tpu_computebound_pairs_per_s": f"{1/t_tpu_comp:.3g}",
            "pim_beats_gpu_exp": str(t_pim < max(t_gpu_mem, t_gpu_comp)),
            "pim_eff_per_w": f"{pim_tput/MEMRISTIVE_PIM.max_power_w:.3g}",
            "gpu_eff_per_w": f"{1/max(t_gpu_mem, t_gpu_comp)/A6000.max_power_w:.3g}",
        })
        rows.append(row)
    return rows


def main():
    run_cli(run)


if __name__ == "__main__":
    main()
