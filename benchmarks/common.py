"""Shared benchmark utilities: timing, CSV emission, and the common CLI.

Every compiler-facing benchmark (fig3/fig4/fig5/fig_fused) accepts the same
flags instead of per-script argument handling:

  --basis {memristive,dram,both}   which logic basis' columns to emit
  --passes fold,cse,fuse,cse,dce   override the IR pass pipeline (empty
                                   string = raw, no optimization passes)

``run_cli(run)`` parses them and calls ``run(basis=..., passes=...)``.
"""

from __future__ import annotations

import argparse
import time

import jax

BASES = ("memristive", "dram")


def parse_args(argv=None) -> argparse.Namespace:
    p = argparse.ArgumentParser(description="ConvPIM benchmark")
    p.add_argument("--basis", choices=(*BASES, "both"), default="both",
                   help="logic basis to report (default: both)")
    p.add_argument("--passes", default=None, metavar="P1,P2,...",
                   help="comma-separated IR pass list overriding the default "
                        "pipeline; pass an empty string for no passes")
    return p.parse_args(argv)


def passes_from_args(args) -> tuple[str, ...] | None:
    """``--passes`` as a pass tuple, or None to keep the default pipeline."""
    if args.passes is None:
        return None
    return tuple(p for p in args.passes.split(",") if p)


def bases_from_args(args) -> tuple[str, ...]:
    return BASES if args.basis == "both" else (args.basis,)


def run_cli(run_fn, argv=None) -> None:
    """Shared benchmark main: parse the common flags, run, emit CSV."""
    args = parse_args(argv)
    emit(run_fn(bases=bases_from_args(args), passes=passes_from_args(args)))


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    assert iters >= 1
    """Median wall time per call in microseconds (CPU; jit-compiled).
    Retries once on transient XLA-CPU compile failures (seen under heavy
    concurrent compilation on 1-core containers)."""
    for attempt in (0, 1):
        try:
            for _ in range(warmup):
                out = fn(*args)
                jax.block_until_ready(out)
            break
        except Exception:  # noqa: BLE001 — transient "Unknown MLIR failure"
            if attempt:
                raise
            jax.clear_caches()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(rows: list[dict]) -> None:
    """Print ``name,us_per_call,derived`` CSV (harness convention)."""
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}")
