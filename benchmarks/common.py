"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    assert iters >= 1
    """Median wall time per call in microseconds (CPU; jit-compiled).
    Retries once on transient XLA-CPU compile failures (seen under heavy
    concurrent compilation on 1-core containers)."""
    for attempt in (0, 1):
        try:
            for _ in range(warmup):
                out = fn(*args)
                jax.block_until_ready(out)
            break
        except Exception:  # noqa: BLE001 — transient "Unknown MLIR failure"
            if attempt:
                raise
            jax.clear_caches()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(rows: list[dict]) -> None:
    """Print ``name,us_per_call,derived`` CSV (harness convention)."""
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}")
