"""CI benchmark smoke: run the fig3/fig4 tables end-to-end and fail loudly.

Benchmark modules are import-time consumers of the whole compiler pipeline
(both logic bases), so running them on CPU catches silent rot — an op that
stops compiling, a basis whose columns go missing, a table that comes back
empty — without asserting any particular performance number.

Usage: ``PYTHONPATH=src python -m benchmarks.smoke``  (exits non-zero on any
exception, empty table, or row with missing values).
"""

from __future__ import annotations

import sys

from . import fig3_arith, fig4_cc

# Columns every row of each table must carry a non-empty value for.
_REQUIRED = {
    "fig3_arith": ("gates_recorded", "dram_maj_gates", "dram_cycles",
                   "dram_peak_rows", "memristive_tops_ours", "dram_tops_ours"),
    "fig4_cc": ("cc", "pim_tops", "dram_cycles", "improvement_vs_gpu_membound"),
}


def check(name: str, rows: list[dict]) -> None:
    if not rows:
        raise SystemExit(f"smoke: {name} produced no rows")
    for row in rows:
        for col in _REQUIRED[name]:
            if row.get(col) in (None, ""):
                raise SystemExit(f"smoke: {name} row {row.get('name')} missing {col!r}")
    print(f"smoke: {name} ok ({len(rows)} rows)", file=sys.stderr)


def main() -> None:
    from .common import emit

    for name, mod in (("fig3_arith", fig3_arith), ("fig4_cc", fig4_cc)):
        rows = mod.run()
        check(name, rows)
        emit(rows)


if __name__ == "__main__":
    main()
