"""CI benchmark smoke: run the compiler-facing tables end-to-end, fail loudly.

Benchmark modules are import-time consumers of the whole compiler pipeline
(both logic bases, single-op and fused multi-op programs), so running them
on CPU catches silent rot — an op that stops compiling, a basis whose
columns go missing, a table that comes back empty — without asserting any
particular performance number.  The compile-cache hit/miss counters are
printed at the end so cache regressions (e.g. a wrapper recompiling what
``compile_op`` already built) are visible in CI logs.

One performance number *is* asserted: the executor-mode benchmark
(``exec_modes``, emitted to ``BENCH_exec.json``) must show the
``pallas-unrolled`` wave-scheduled kernel beating the ``pallas-loop``
fori_loop kernel on the f32 fused MAC — the perf trajectory this PR seeds.

Usage: ``PYTHONPATH=src python -m benchmarks.smoke``  (exits non-zero on any
exception, empty table, row with missing values, or executor perf
regression).
"""

from __future__ import annotations

import sys

from repro.core import ir

from . import fig3_arith, fig4_cc, fig5_matmul, fig_fused

# Columns every row of each table must carry a non-empty value for.
_REQUIRED = {
    "fig3_arith": ("gates_recorded", "dram_maj_gates", "dram_cycles",
                   "dram_peak_rows", "memristive_tops_ours", "dram_tops_ours",
                   "parallel_cycles", "cols_peak_unsched"),
    "fig4_cc": ("cc", "pim_tops", "dram_cycles", "improvement_vs_gpu_membound"),
    "fig5_matmul": ("reuse_flops_per_byte", "pim_pairs_per_s",
                    "memristive_fusedmac_pairs_per_s", "dram_fusedmac_pairs_per_s",
                    "tpu_membound_pairs_per_s"),
    "fig_fused": ("memristive_gates_fused", "memristive_gates_separate",
                  "memristive_hbm_planes_fused", "dram_cycles_fused",
                  "dram_hbm_planes_separate", "memristive_macs_per_s",
                  "hbm_bytes_fused", "memristive_parallel_cycles_fused",
                  "memristive_peak_cols_unsched"),
}


def check(name: str, rows: list[dict]) -> None:
    if not rows:
        raise SystemExit(f"smoke: {name} produced no rows")
    for row in rows:
        for col in _REQUIRED[name]:
            if row.get(col) in (None, ""):
                raise SystemExit(f"smoke: {name} row {row.get('name')} missing {col!r}")
    print(f"smoke: {name} ok ({len(rows)} rows)", file=sys.stderr)


def check_exec(rows: list[dict]) -> None:
    """The unrolled kernel must beat the fori_loop kernel on the f32 MAC."""
    us = {r["name"]: float(r["us_per_call"]) for r in rows}
    loop = us.get("exec/f32_mac/memristive/pallas-loop")
    unrolled = us.get("exec/f32_mac/memristive/pallas-unrolled")
    if loop is None or unrolled is None:
        raise SystemExit("smoke: exec_modes is missing the f32 MAC rows")
    if unrolled >= loop:
        raise SystemExit(
            f"smoke: pallas-unrolled ({unrolled:.0f}us) is not faster than "
            f"the fori_loop kernel ({loop:.0f}us) on the f32 fused MAC")
    print(f"smoke: exec ok (f32 MAC unrolled {unrolled:.0f}us vs "
          f"loop {loop:.0f}us, {loop / unrolled:.1f}x)", file=sys.stderr)


def main() -> None:
    from .common import emit
    from .run import write_exec_json

    for name, mod in (("fig3_arith", fig3_arith), ("fig4_cc", fig4_cc),
                      ("fig_fused", fig_fused), ("fig5_matmul", fig5_matmul)):
        rows = mod.run()
        check(name, rows)
        emit(rows)
    check_exec(write_exec_json("BENCH_exec.json"))
    stats = ir.cache_stats()
    print(f"smoke: compile cache hits={stats['hits']} misses={stats['misses']}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
