"""Benchmark harness: one module per paper table/figure.

  fig3_arith      — §3 vectored arithmetic throughput/efficiency
  fig4_cc         — §3 compute-complexity vs improvement
  fig_fused       — fused multi-op programs (MAC) vs separate dispatches
  fig5_matmul     — §4 batched matmul reuse crossover
  fig6_cnn_infer  — §5 CNN inference
  fig7_cnn_train  — §5 CNN training
  roofline_table  — deliverable (g): per-cell three-term roofline + Fig-8 verdicts

Prints ``name,us_per_call,derived`` CSV.  The executor-mode shootout
(``exec_modes``, unrolled vs fori_loop) is not part of the default sweep —
its straight-line compile is expensive; run it via ``--json PATH`` (which
runs only that benchmark and writes its rows as JSON, the
``BENCH_exec.json`` perf-trajectory artifact checked by CI), via
``python -m benchmarks.exec_modes``, or via ``benchmarks.smoke``.
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback


def write_exec_json(path: str) -> list[dict]:
    """Run the executor-mode benchmark and write its rows to ``path``."""
    from . import exec_modes
    from .common import emit

    rows = exec_modes.run()
    with open(path, "w") as f:
        json.dump({"benchmark": "exec_modes", "rows": rows}, f, indent=2,
                  sort_keys=True)
        f.write("\n")
    emit([dict(r) for r in rows])
    return rows


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description="ConvPIM benchmark harness")
    parser.add_argument(
        "--json", metavar="BENCH_exec.json", default=None,
        help="run only the executor-mode benchmark and write its rows "
             "(gates, num_cols, waves, us per executor mode) as JSON")
    args = parser.parse_args(argv)

    if args.json is not None:
        write_exec_json(args.json)
        return

    from . import (fig3_arith, fig4_cc, fig5_matmul, fig6_cnn_infer,
                   fig7_cnn_train, fig_fused, roofline_table)
    from .common import emit

    failures = 0
    for mod in (fig3_arith, fig4_cc, fig_fused, fig5_matmul, fig6_cnn_infer,
                fig7_cnn_train, roofline_table):
        try:
            emit(mod.run())
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
