"""Benchmark harness: one module per paper table/figure.

  fig3_arith      — §3 vectored arithmetic throughput/efficiency
  fig4_cc         — §3 compute-complexity vs improvement
  fig_fused       — fused multi-op programs (MAC) vs separate dispatches
  fig5_matmul     — §4 batched matmul reuse crossover
  fig6_cnn_infer  — §5 CNN inference
  fig7_cnn_train  — §5 CNN training
  roofline_table  — deliverable (g): per-cell three-term roofline + Fig-8 verdicts

Prints ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (fig3_arith, fig4_cc, fig5_matmul, fig6_cnn_infer,
                   fig7_cnn_train, fig_fused, roofline_table)
    from .common import emit

    failures = 0
    for mod in (fig3_arith, fig4_cc, fig_fused, fig5_matmul, fig6_cnn_infer,
                fig7_cnn_train, roofline_table):
        try:
            emit(mod.run())
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
