"""Paper Fig 3: throughput + energy efficiency of vectored arithmetic.

Columns per op: our recorded netlist gates, the post-pipeline optimized gate
count and peak live columns from the ``repro.core.ir`` compiler (one compile
cache shared with kernels/simulate/analyzer), paper-calibrated gates, modeled
PIM throughput (memristive/DRAM, ours + paper), GPU measured/theoretical from
the paper, and the TPU v5e memory-bound/compute-bound equivalents.  Beyond
the paper's 32-bit set, the multi-precision rows (int8/int16 fixed, bf16
float) quantify the paper's bit-serial scaling argument: gates fall
superlinearly with precision.

The DRAM rows are *independently derived* from the ``dram``-basis
compilation of the same netlists — MAJ3/NOT gate counts, AAP/TRA
row-command cycles and peak rows (including the reserved compute-row
group) — no longer the paper's clock-scaled memristive schedules; the
clock-scaled figure is kept as ``dram_tops_clock_scaled`` for comparison.

The us_per_call column times the bit-exact simulation (execute-mode PlaneVM
on CPU) — correctness wall-time, not the modeled hardware number.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import ir, simulate
from repro.core.costmodel import (
    A6000,
    DRAM_PIM,
    MEMRISTIVE_PIM,
    PAPER_GATE_COUNTS,
    PAPER_GPU_MEASURED,
    PAPER_PIM_THROUGHPUT,
    TPU_V5E,
)

from .common import BASES, run_cli, time_fn

N_ELEMS = 4096

# name -> (sim fn, ir op key, nbits, input kind)
_OPS = {
    "fixed8_add": (lambda x, y: simulate.fixed_add(x, y, nbits=8)[0], "fixed_add", 8, "int8"),
    "fixed8_mul": (lambda x, y: simulate.fixed_mul(x, y, nbits=8)[0], "fixed_mul", 8, "int8"),
    "fixed16_add": (lambda x, y: simulate.fixed_add(x, y, nbits=16)[0], "fixed_add", 16, "int16"),
    "fixed16_mul": (lambda x, y: simulate.fixed_mul(x, y, nbits=16)[0], "fixed_mul", 16, "int16"),
    "fixed32_add": (lambda x, y: simulate.fixed_add(x, y)[0], "fixed_add", 32, "int32"),
    "fixed32_mul": (lambda x, y: simulate.fixed_mul(x, y)[0], "fixed_mul", 32, "int32"),
    "bf16_add": (lambda x, y: simulate.bf16_add(x, y)[0], "bf16_add", 16, "bf16"),
    "bf16_mul": (lambda x, y: simulate.bf16_mul(x, y)[0], "bf16_mul", 16, "bf16"),
    "float32_add": (lambda x, y: simulate.float_add(x, y)[0], "float_add", 32, "f32"),
    "float32_mul": (lambda x, y: simulate.float_mul(x, y)[0], "float_mul", 32, "f32"),
    "float32_div": (lambda x, y: simulate.float_div(x, y)[0], "float_div", 32, "f32"),
}


def _inputs(kind: str, rng: np.random.Generator):
    if kind.startswith("int"):
        nbits = int(kind[3:])
        lo, hi = -(2 ** (nbits - 1)), 2 ** (nbits - 1)
        x = rng.integers(lo, hi, N_ELEMS, dtype=np.int64).astype(np.int32)
        y = rng.integers(lo, hi, N_ELEMS, dtype=np.int64).astype(np.int32)
        return jnp.asarray(x), jnp.asarray(y)
    x = rng.standard_normal(N_ELEMS).astype(np.float32)
    y = rng.standard_normal(N_ELEMS).astype(np.float32)
    if kind == "bf16":
        return jnp.asarray(x, jnp.bfloat16), jnp.asarray(y, jnp.bfloat16)
    return jnp.asarray(x), jnp.asarray(y)


def run(bases: tuple[str, ...] = BASES,
        passes: tuple[str, ...] | None = None) -> list[dict]:
    rng = np.random.default_rng(0)
    passes = ir.DEFAULT_PASSES if passes is None else passes
    rows = []
    for op, (sim, ir_key, nbits, kind) in _OPS.items():
        x, y = _inputs(kind, rng)
        rep = ir.op_cost(ir_key, nbits, passes)  # warm the cache before timing
        # eager bit-exact simulation: the 12k–24k-op unrolled mul/div
        # netlists exceed an XLA-CPU MLIR pipeline limit under jit; the
        # column is correctness wall-time, not modeled hardware time
        us = time_fn(sim, x, y, warmup=0, iters=1)
        ours = rep.recorded_gates
        paper = PAPER_GATE_COUNTS.get(op)  # None for ops with no Fig-3 reference
        bytes_per_op = 3 * (nbits // 8)  # 2 reads + 1 write
        # the same pipeline minus the pressure scheduler, to show its win
        unsched = tuple(p for p in passes if p != "reorder")
        rep_unsched = ir.op_cost(ir_key, nbits, unsched)
        row = {
            "name": f"fig3/{op}",
            "us_per_call": f"{us:.0f}",
            "gates_recorded": ours,
            "gates_optimized": rep.gates,  # post-pipeline (≤ recorded)
            "cols_peak": rep.num_cols,  # ≤ the 1024-column crossbar budget
            "cols_peak_unsched": rep_unsched.num_cols,  # without `reorder`
            "parallel_cycles": rep.parallel_cycles,  # dependency waves
            "gates_paper": paper if paper is not None else "n/a",
        }
        if "memristive" in bases:
            row.update({
                "memristive_tops_ours": f"{MEMRISTIVE_PIM.op_throughput(ours)/1e12:.2f}",
                "memristive_tops_optimized": f"{MEMRISTIVE_PIM.op_throughput(rep.gates)/1e12:.2f}",
                # upper bound if every dependency wave fired in one cycle
                "memristive_tops_parallel":
                    f"{MEMRISTIVE_PIM.report_parallel_throughput(rep)/1e12:.2f}",
                "memristive_tops_paper_model": (
                    f"{MEMRISTIVE_PIM.op_throughput(paper)/1e12:.2f}"
                    if paper is not None else "n/a"
                ),
                "memristive_tops_paper_fig3": (
                    f"{PAPER_PIM_THROUGHPUT[('memristive', op)]/1e12:.2f}"
                    if ('memristive', op) in PAPER_PIM_THROUGHPUT else "n/a"
                ),
            })
        if "dram" in bases:
            # independently derived dram-basis columns (MAJ3/NOT lowering)
            rep_dram = ir.op_cost(ir_key, nbits, passes, basis="dram")
            row.update({
                "dram_maj_gates": rep_dram.maj_gates,
                "dram_not_gates": rep_dram.not_gates,
                "dram_cycles": rep_dram.cycles,
                "dram_peak_rows": rep_dram.peak_rows,
                "dram_tops_ours": f"{DRAM_PIM.report_throughput(rep_dram)/1e12:.4f}",
                "dram_tops_clock_scaled": f"{DRAM_PIM.op_throughput(ours)/1e12:.4f}",
                "dram_tops_paper_fig3": (
                    f"{PAPER_PIM_THROUGHPUT[('dram', op)]/1e12:.4f}"
                    if ('dram', op) in PAPER_PIM_THROUGHPUT else "n/a"
                ),
            })
        row.update({
            "gpu_measured_tops": f"{PAPER_GPU_MEASURED.get(op, 0.057e12)/1e12:.3f}",
            "gpu_theoretical_tops": f"{A6000.compute_throughput()/1e12:.1f}",
            "tpu_membound_tops": f"{TPU_V5E.hbm_bw/bytes_per_op/1e12:.3f}",
            "tpu_peak_tops": f"{TPU_V5E.peak_bf16/1e12:.0f}",
            "memr_tops_per_w_ours": f"{MEMRISTIVE_PIM.op_throughput_per_watt(ours)/1e9:.2f}G",
            "gpu_membound_per_w": f"{PAPER_GPU_MEASURED.get(op, 0.057e12)/A6000.max_power_w/1e9:.3f}G",
        })
        rows.append(row)
    return rows


def main():
    run_cli(run)


if __name__ == "__main__":
    main()
