"""Paper Fig 3: throughput + energy efficiency of vectored 32-bit arithmetic.

Columns per op: our netlist gates, paper-calibrated gates, modeled PIM
throughput (memristive/DRAM, ours + paper), GPU measured/theoretical from the
paper, and the TPU v5e memory-bound/compute-bound equivalents.  The
us_per_call column times the bit-exact simulation (execute-mode PlaneVM on
CPU) for a 4096-element vector — correctness wall-time, not the modeled
hardware number.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aritpim, simulate
from repro.core.costmodel import (
    A6000,
    DRAM_PIM,
    MEMRISTIVE_PIM,
    PAPER_GATE_COUNTS,
    PAPER_GPU_MEASURED,
    PAPER_PIM_THROUGHPUT,
    TPU_V5E,
)

from .common import time_fn

N_ELEMS = 4096

_SIM = {
    "fixed32_add": lambda x, y: simulate.fixed_add(x, y)[0],
    "fixed32_mul": lambda x, y: simulate.fixed_mul(x, y)[0],
    "float32_add": lambda x, y: simulate.float_add(x, y)[0],
    "float32_mul": lambda x, y: simulate.float_mul(x, y)[0],
    "float32_div": lambda x, y: simulate.float_div(x, y)[0],
}

_OUR_GATES = {
    "fixed32_add": lambda: aritpim.count_gates(aritpim.fixed_add, 32, 32),
    "fixed32_mul": lambda: aritpim.count_gates(aritpim.fixed_mul_signed, 32, 32),
    "float32_add": lambda: aritpim.count_gates(aritpim.float_add, 32, 32),
    "float32_mul": lambda: aritpim.count_gates(aritpim.float_mul, 32, 32),
    "float32_div": lambda: aritpim.count_gates(aritpim.float_div, 32, 32),
}


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    for op, sim in _SIM.items():
        if "fixed" in op:
            x = rng.integers(-2**31, 2**31, N_ELEMS, dtype=np.int64).astype(np.int32)
            y = rng.integers(-2**31, 2**31, N_ELEMS, dtype=np.int64).astype(np.int32)
        else:
            x = rng.standard_normal(N_ELEMS).astype(np.float32)
            y = rng.standard_normal(N_ELEMS).astype(np.float32)
        # eager bit-exact simulation: the 12k–24k-op unrolled mul/div
        # netlists exceed an XLA-CPU MLIR pipeline limit under jit; the
        # column is correctness wall-time, not modeled hardware time
        us = time_fn(sim, jnp.asarray(x), jnp.asarray(y), warmup=0, iters=1)
        ours = _OUR_GATES[op]()
        paper = PAPER_GATE_COUNTS.get(op, ours)  # div: no Fig-3 reference point
        bytes_per_op = 12  # 2×4B read + 4B write
        rows.append({
            "name": f"fig3/{op}",
            "us_per_call": f"{us:.0f}",
            "gates_ours": ours,
            "gates_paper": paper,
            "memristive_tops_ours": f"{MEMRISTIVE_PIM.op_throughput(ours)/1e12:.2f}",
            "memristive_tops_paper_model": f"{MEMRISTIVE_PIM.op_throughput(paper)/1e12:.2f}",
            "memristive_tops_paper_fig3": (
                f"{PAPER_PIM_THROUGHPUT[('memristive', op)]/1e12:.2f}"
                if ('memristive', op) in PAPER_PIM_THROUGHPUT else "n/a"
            ),
            "dram_tops_ours": f"{DRAM_PIM.op_throughput(ours)/1e12:.4f}",
            "dram_tops_paper_fig3": (
                f"{PAPER_PIM_THROUGHPUT[('dram', op)]/1e12:.4f}"
                if ('dram', op) in PAPER_PIM_THROUGHPUT else "n/a"
            ),
            "gpu_measured_tops": f"{PAPER_GPU_MEASURED.get(op, 0.057e12)/1e12:.3f}",
            "gpu_theoretical_tops": f"{A6000.compute_throughput()/1e12:.1f}",
            "tpu_membound_tops": f"{TPU_V5E.hbm_bw/bytes_per_op/1e12:.3f}",
            "tpu_peak_tops": f"{TPU_V5E.peak_bf16/1e12:.0f}",
            "memr_tops_per_w_ours": f"{MEMRISTIVE_PIM.op_throughput_per_watt(ours)/1e9:.2f}G",
            "gpu_membound_per_w": f"{PAPER_GPU_MEASURED.get(op, 0.057e12)/A6000.max_power_w/1e9:.3f}G",
        })
    return rows


def main():
    from .common import emit

    emit(run())


if __name__ == "__main__":
    main()
