"""Paper Fig 7: full-precision CNN training (fwd+bwd) — PIM vs GPU/TPU."""

from __future__ import annotations

from .fig6_cnn_infer import run as _run


def run() -> list[dict]:
    return _run(train=True)


def main():
    from .common import emit

    emit(run())


if __name__ == "__main__":
    main()
