"""Paper Fig 6: full-precision CNN inference — PIM upper bound vs GPU/TPU.

Methodology as the paper's §5: the PIM number counts only the matmul/conv
MACs (an upper bound); the accelerator numbers come from the compiled step's
cost analysis (flops, bytes — our stand-in for the Nsight counters).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analyzer import Workload, analyze, pim_time
from repro.core.costmodel import A6000, MEMRISTIVE_PIM, TPU_V5E
from repro.models import cnn

from .common import time_fn

BATCH = 8


def _measure(name: str, train: bool = False):
    init, apply = cnn.MODELS[name]
    params = init(jax.random.PRNGKey(0))
    x = jnp.zeros((BATCH, 224, 224, 3), jnp.float32)

    if train:
        def step(p, x):
            def loss(p):
                out = apply(p, x, train=True)
                return (out.astype(jnp.float32) ** 2).mean()
            return jax.grad(loss)(p)
        fn = jax.jit(step)
    else:
        fn = jax.jit(lambda p, x: apply(p, x))
    lowered = fn.lower(params, x)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    # fusion-aware bytes: raw CPU 'bytes accessed' understates reuse ~5-30×
    # (unfused elementwise), which would flip the paper's Fig-7 verdict
    from repro.core.roofline import analyze_hlo

    a = analyze_hlo(compiled.as_text(), default_group=1)
    bytes_ = a.hbm_bytes or float(ca.get("bytes accessed", 0.0))
    us = time_fn(fn, params, x, warmup=1, iters=2)
    return float(ca.get("flops", 0.0)), bytes_, us


def run(train: bool = False) -> list[dict]:
    rows = []
    for name in ("alexnet", "googlenet", "resnet50"):
        flops, bytes_, us = _measure(name, train=train)
        w = Workload(name, flops=flops, hbm_bytes=bytes_)
        t_pim = pim_time(w)  # matmul/conv MACs only — paper's upper bound
        t_gpu_comp = flops / A6000.peak_fp32
        t_gpu_mem = bytes_ / A6000.mem_bw
        t_gpu = max(t_gpu_comp, t_gpu_mem)
        t_tpu = max(flops / TPU_V5E.peak_bf16, bytes_ / TPU_V5E.hbm_bw)
        tag = "fig7" if train else "fig6"
        rows.append({
            "name": f"{tag}/{name}",
            "us_per_call": f"{us:.0f}",
            "flops_per_batch": f"{flops:.3g}",
            "reuse_flops_per_byte": f"{flops/bytes_:.1f}",
            "pim_imgs_per_s": f"{BATCH/t_pim:.1f}",
            "gpu_exp_imgs_per_s": f"{BATCH/t_gpu:.1f}",
            "gpu_theo_imgs_per_s": f"{BATCH/t_gpu_comp:.1f}",
            "tpu_imgs_per_s": f"{BATCH/t_tpu:.1f}",
            "pim_beats_gpu": str(t_pim < t_gpu),
            "pim_eff_imgs_per_j": f"{BATCH/t_pim/MEMRISTIVE_PIM.max_power_w:.2f}",
            "gpu_eff_imgs_per_j": f"{BATCH/t_gpu/A6000.max_power_w:.2f}",
        })
    return rows


def main():
    from .common import emit

    emit(run(train=False))


if __name__ == "__main__":
    main()
