"""Paper Fig 4: inverse relationship between compute complexity and
improvement over the memory-bound GPU.

I/O widths come from ``aritpim._OP_TABLE`` metadata (``op_io_bits``), not
from op-name string matching, and the DRAM columns are the independently
derived MAJ3/NOT compilation of each netlist (gate counts, AAP/TRA cycles,
peak rows) rather than clock-scaled memristive schedules.
"""

from __future__ import annotations

from repro.core import ir, metrics
from repro.core.aritpim import op_io_bits
from repro.core.costmodel import A6000, DRAM_PIM, MEMRISTIVE_PIM, PAPER_GATE_COUNTS, TPU_V5E

from .common import BASES, run_cli

# Fig-3/4 op name -> (aritpim._OP_TABLE key, nbits)
_FIG_OPS = {
    "fixed32_add": ("fixed_add", 32),
    "fixed32_mul": ("fixed_mul", 32),
    "float32_add": ("float_add", 32),
    "float32_mul": ("float_mul", 32),
}


def run(bases: tuple[str, ...] = BASES,
        passes: tuple[str, ...] | None = None) -> list[dict]:
    rows = []
    passes = ir.DEFAULT_PASSES if passes is None else passes
    io_bits = {name: op_io_bits(key, nbits) for name, (key, nbits) in _FIG_OPS.items()}
    pts = metrics.fig4_points(MEMRISTIVE_PIM, A6000, PAPER_GATE_COUNTS, io_bits=io_bits)
    for p in sorted(pts, key=lambda q: q.cc):
        key, nbits = _FIG_OPS[p.op]
        # the TPU-era column: same CC axis, improvement vs v5e HBM bound
        io_bytes = io_bits[p.op] // 8
        tpu_membound = TPU_V5E.hbm_bw / io_bytes
        row = {
            "name": f"fig4/{p.op}",
            "us_per_call": "",
            "cc": f"{p.cc:.2f}",
            "pim_tops": f"{p.pim_throughput/1e12:.2f}",
        }
        if "dram" in bases:
            rep_dram = ir.op_cost(key, nbits, passes, basis="dram")
            dram_tops = DRAM_PIM.report_throughput(rep_dram)
            row.update({
                "dram_maj_gates": rep_dram.maj_gates,
                "dram_cycles": rep_dram.cycles,
                "dram_peak_rows": rep_dram.peak_rows,
                "dram_tops": f"{dram_tops/1e12:.4f}",
                "dram_improvement_vs_gpu_membound": (
                    f"{dram_tops/(A6000.membound_throughput(io_bytes)):.3f}x"
                ),
            })
        row.update({
            "improvement_vs_gpu_membound": f"{p.improvement:.1f}x",
            "improvement_vs_tpu_membound": f"{p.pim_throughput/tpu_membound:.1f}x",
        })
        rows.append(row)
    return rows


def main():
    run_cli(run)


if __name__ == "__main__":
    main()
