"""Paper Fig 4: inverse relationship between compute complexity and
improvement over the memory-bound GPU."""

from __future__ import annotations

from repro.core import metrics
from repro.core.costmodel import A6000, MEMRISTIVE_PIM, PAPER_GATE_COUNTS, TPU_V5E


def run() -> list[dict]:
    rows = []
    pts = metrics.fig4_points(MEMRISTIVE_PIM, A6000, PAPER_GATE_COUNTS)
    for p in sorted(pts, key=lambda q: q.cc):
        # the TPU-era column: same CC axis, improvement vs v5e HBM bound
        nbits = 32
        io_bytes = (4 if "mul" in p.op and "fixed" in p.op else 3) * nbits // 8
        tpu_membound = TPU_V5E.hbm_bw / io_bytes
        rows.append({
            "name": f"fig4/{p.op}",
            "us_per_call": "",
            "cc": f"{p.cc:.2f}",
            "pim_tops": f"{p.pim_throughput/1e12:.2f}",
            "improvement_vs_gpu_membound": f"{p.improvement:.1f}x",
            "improvement_vs_tpu_membound": f"{p.pim_throughput/tpu_membound:.1f}x",
        })
    return rows


def main():
    from .common import emit

    emit(run())


if __name__ == "__main__":
    main()
