"""Fused multi-op programs vs separate dispatches — the in-memory payoff.

The paper's core claim is that digital PIM wins exactly when intermediate
results stay in the array (Fig 3/Fig 8).  This table quantifies it with the
``repro.pim`` trace-and-compile frontend: the fused MAC ``a*b + c`` compiled
as **one** schedule vs separate ``mul`` then ``add`` dispatches whose
product planes round-trip through HBM.  Per dtype and basis it reports

* native gates and per-basis command cycles, fused vs the separate-dispatch
  sum.  The separate baseline is what the public wrappers actually dispatch
  (for fixed point that is the *truncated* low-half product program, so the
  gate comparison isolates true cross-op fusion wins from the truncation
  win; the legacy full-width ``_OP_TABLE`` dispatch is kept as
  ``*_separate_fullwidth`` for fixed rows),
* peak live columns/rows vs the paper's 1024 budget, and
* HBM traffic — plane counts and bytes (``PIMConfig.report_hbm_bytes``):
  the fused program moves only its true inputs and outputs, never the
  intermediate product planes.

``us_per_call`` times the fused interpreter execution on 4096 elements.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

import repro.pim as pim
from repro.core import ir
from repro.core.costmodel import DRAM_PIM, MEMRISTIVE_PIM

from .common import BASES, run_cli, time_fn

N_ELEMS = 4096

# row name -> (PimType, full-width _OP_TABLE keys + nbits for fixed rows)
_CASES = {
    "f32_mac": (pim.f32, None),
    "bf16_mac": (pim.bf16, None),
    "int16_mac": (pim.int16, (("fixed_mul", "fixed_add"), 16)),
    "int8_mac": (pim.int8, (("fixed_mul", "fixed_add"), 8)),
}

_CONFIGS = {"memristive": MEMRISTIVE_PIM, "dram": DRAM_PIM}


def _inputs(dtype, rng):
    if dtype.kind == "fixed":
        lo, hi = -(2 ** (dtype.nbits - 1)), 2 ** (dtype.nbits - 1)
        return tuple(
            jnp.asarray(rng.integers(lo, hi, N_ELEMS).astype(np.int32))
            for _ in range(3)
        )
    xs = tuple(rng.standard_normal(N_ELEMS).astype(np.float32) for _ in range(3))
    if dtype.kind == "bf16":
        return tuple(jnp.asarray(x, jnp.bfloat16) for x in xs)
    return tuple(jnp.asarray(x) for x in xs)


def run(bases: tuple[str, ...] = BASES,
        passes: tuple[str, ...] | None = None) -> list[dict]:
    passes = ir.DEFAULT_PASSES if passes is None else passes
    rng = np.random.default_rng(0)
    rows = []
    for name, (dtype, fullwidth) in _CASES.items():
        mac = pim.compile(lambda a, b, c: a * b + c, dtype=dtype)
        # what separate dispatches through the public wrappers actually run
        sep_mul = pim.compile(lambda a, b: a * b, dtype=dtype)
        sep_add = pim.compile(lambda a, b: a + b, dtype=dtype)
        x, y, c = _inputs(dtype, rng)
        mac.compiled(passes=passes)  # warm the cache before timing
        us = time_fn(
            lambda: mac(x, y, c, passes=passes, backend="interpreter"),
            warmup=0, iters=1)
        fused_mem = mac.cost(passes=passes)
        row = {
            "name": f"fig_fused/{name}",
            "us_per_call": f"{us:.0f}",
            "hbm_bytes_fused":
                f"{MEMRISTIVE_PIM.report_hbm_bytes(fused_mem, N_ELEMS):.0f}",
        }
        unsched = tuple(p for p in passes if p != "reorder")
        for basis in bases:
            fused = mac.cost(basis=basis, passes=passes)
            fused_unsched = mac.cost(basis=basis, passes=unsched)
            seps = [sep_mul.cost(basis=basis, passes=passes),
                    sep_add.cost(basis=basis, passes=passes)]
            cfg = _CONFIGS[basis]
            row.update({
                f"{basis}_gates_fused": fused.gates,
                f"{basis}_gates_separate": sum(r.gates for r in seps),
                f"{basis}_cycles_fused": fused.cycles,
                f"{basis}_cycles_separate": sum(r.cycles for r in seps),
                f"{basis}_parallel_cycles_fused": fused.parallel_cycles,
                f"{basis}_peak_cols_fused": fused.num_cols,
                f"{basis}_peak_cols_unsched": fused_unsched.num_cols,
                f"{basis}_peak_rows_fused": fused.peak_rows,
                f"{basis}_hbm_planes_fused": fused.hbm_planes,
                f"{basis}_hbm_planes_separate": sum(r.hbm_planes for r in seps),
                f"{basis}_hbm_saving":
                    f"{sum(r.hbm_planes for r in seps)/fused.hbm_planes:.2f}x",
                f"{basis}_macs_per_s": f"{cfg.report_throughput(fused)/1e12:.4f}T",
            })
            if fullwidth is not None:
                ops_keys, nbits = fullwidth
                full = sum(
                    ir.op_cost(k, nbits, passes, basis=basis).gates
                    for k in ops_keys)
                row[f"{basis}_gates_separate_fullwidth"] = full
        rows.append(row)
    return rows


def main():
    run_cli(run)


if __name__ == "__main__":
    main()
